//! Expressions of the kernel IR.

use super::types::{Ty, Val};
use std::fmt;

/// Binary operators. Comparison/logical ops yield `I(0|1)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Rem,
    Min,
    Max,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
    And,
    Or,
}

impl BinOp {
    pub fn is_cmp(self) -> bool {
        matches!(self, BinOp::Lt | BinOp::Le | BinOp::Gt | BinOp::Ge | BinOp::Eq | BinOp::Ne)
    }

    pub fn c_symbol(self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
            BinOp::Rem => "%",
            BinOp::Min => "min",
            BinOp::Max => "max",
            BinOp::Lt => "<",
            BinOp::Le => "<=",
            BinOp::Gt => ">",
            BinOp::Ge => ">=",
            BinOp::Eq => "==",
            BinOp::Ne => "!=",
            BinOp::And => "&&",
            BinOp::Or => "||",
        }
    }
}

/// Unary operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnOp {
    Neg,
    Not,
    /// int -> float conversion
    IToF,
    /// float -> int truncation
    FToI,
    Sqrt,
    Exp,
    Abs,
}

/// An IR expression tree. `Load` is a *global memory* read — the operation
/// the whole paper is about; local scalars are `Var`s.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// Integer literal.
    I(i64),
    /// Float literal.
    F(f32),
    /// Local scalar variable (includes loop induction variables).
    Var(String),
    /// Scalar kernel parameter (runtime constant, e.g. `num_nodes`).
    Param(String),
    /// NDRange builtin `get_global_id(dim)` (only valid in NDRange kernels).
    GlobalId(u8),
    /// Global-memory read: `buf[idx]`.
    Load { buf: String, idx: Box<Expr> },
    Bin(BinOp, Box<Expr>, Box<Expr>),
    Un(UnOp, Box<Expr>),
    /// `cond ? t : f`
    Select(Box<Expr>, Box<Expr>, Box<Expr>),
}

impl Expr {
    /// True if the expression contains any global `Load`.
    pub fn has_load(&self) -> bool {
        let mut found = false;
        self.visit(&mut |e| {
            if matches!(e, Expr::Load { .. }) {
                found = true;
            }
        });
        found
    }

    /// Number of `Load` nodes.
    pub fn load_count(&self) -> usize {
        let mut n = 0;
        self.visit(&mut |e| {
            if matches!(e, Expr::Load { .. }) {
                n += 1;
            }
        });
        n
    }

    /// Pre-order visit of every sub-expression.
    pub fn visit(&self, f: &mut impl FnMut(&Expr)) {
        f(self);
        match self {
            Expr::Load { idx, .. } => idx.visit(f),
            Expr::Bin(_, a, b) => {
                a.visit(f);
                b.visit(f);
            }
            Expr::Un(_, a) => a.visit(f),
            Expr::Select(c, t, e) => {
                c.visit(f);
                t.visit(f);
                e.visit(f);
            }
            _ => {}
        }
    }

    /// Collect the names of all `Var`s referenced.
    pub fn vars(&self, out: &mut Vec<String>) {
        self.visit(&mut |e| {
            if let Expr::Var(v) = e {
                if !out.contains(v) {
                    out.push(v.clone());
                }
            }
        });
    }

    /// Rewrite the tree bottom-up with `f` applied to every node.
    pub fn map(self, f: &impl Fn(Expr) -> Expr) -> Expr {
        let e = match self {
            Expr::Load { buf, idx } => Expr::Load { buf, idx: Box::new(idx.map(f)) },
            Expr::Bin(op, a, b) => Expr::Bin(op, Box::new(a.map(f)), Box::new(b.map(f))),
            Expr::Un(op, a) => Expr::Un(op, Box::new(a.map(f))),
            Expr::Select(c, t, e2) => Expr::Select(
                Box::new(c.map(f)),
                Box::new(t.map(f)),
                Box::new(e2.map(f)),
            ),
            other => other,
        };
        f(e)
    }

    /// Substitute every `Var(name)` with `repl`.
    pub fn subst_var(self, name: &str, repl: &Expr) -> Expr {
        self.map(&|e| match &e {
            Expr::Var(v) if v == name => repl.clone(),
            _ => e,
        })
    }

    /// Evaluate a binary op on runtime values (float semantics if either
    /// side is float, like C's usual arithmetic conversions).
    pub fn eval_bin(op: BinOp, a: Val, b: Val) -> Val {
        use BinOp::*;
        let float = matches!(a, Val::F(_)) || matches!(b, Val::F(_));
        if float {
            let (x, y) = (a.as_f(), b.as_f());
            match op {
                Add => Val::F(x + y),
                Sub => Val::F(x - y),
                Mul => Val::F(x * y),
                Div => Val::F(x / y),
                Rem => Val::F(x % y),
                Min => Val::F(x.min(y)),
                Max => Val::F(x.max(y)),
                Lt => Val::I((x < y) as i64),
                Le => Val::I((x <= y) as i64),
                Gt => Val::I((x > y) as i64),
                Ge => Val::I((x >= y) as i64),
                Eq => Val::I((x == y) as i64),
                Ne => Val::I((x != y) as i64),
                And => Val::I((x != 0.0 && y != 0.0) as i64),
                Or => Val::I((x != 0.0 || y != 0.0) as i64),
            }
        } else {
            let (x, y) = (a.as_i(), b.as_i());
            match op {
                Add => Val::I(x.wrapping_add(y)),
                Sub => Val::I(x.wrapping_sub(y)),
                Mul => Val::I(x.wrapping_mul(y)),
                Div => Val::I(if y == 0 { 0 } else { x / y }),
                Rem => Val::I(if y == 0 { 0 } else { x % y }),
                Min => Val::I(x.min(y)),
                Max => Val::I(x.max(y)),
                Lt => Val::I((x < y) as i64),
                Le => Val::I((x <= y) as i64),
                Gt => Val::I((x > y) as i64),
                Ge => Val::I((x >= y) as i64),
                Eq => Val::I((x == y) as i64),
                Ne => Val::I((x != y) as i64),
                And => Val::I((x != 0 && y != 0) as i64),
                Or => Val::I((x != 0 || y != 0) as i64),
            }
        }
    }

    /// Evaluate a unary op.
    pub fn eval_un(op: UnOp, a: Val) -> Val {
        match op {
            UnOp::Neg => match a {
                Val::I(v) => Val::I(-v),
                Val::F(v) => Val::F(-v),
            },
            UnOp::Not => Val::I(!a.is_true() as i64),
            UnOp::IToF => Val::F(a.as_f()),
            UnOp::FToI => Val::I(a.as_i()),
            UnOp::Sqrt => Val::F(a.as_f().sqrt()),
            UnOp::Exp => Val::F(a.as_f().exp()),
            UnOp::Abs => match a {
                Val::I(v) => Val::I(v.abs()),
                Val::F(v) => Val::F(v.abs()),
            },
        }
    }

    /// Static result type under a typing environment (vars/params -> Ty).
    pub fn ty_in(&self, lookup: &impl Fn(&str) -> Option<Ty>, buf_ty: &impl Fn(&str) -> Option<Ty>) -> Option<Ty> {
        match self {
            Expr::I(_) | Expr::GlobalId(_) => Some(Ty::I32),
            Expr::F(_) => Some(Ty::F32),
            Expr::Var(v) | Expr::Param(v) => lookup(v),
            Expr::Load { buf, .. } => buf_ty(buf),
            Expr::Bin(op, a, b) => {
                if op.is_cmp() || matches!(op, BinOp::And | BinOp::Or) {
                    Some(Ty::I32)
                } else {
                    match (a.ty_in(lookup, buf_ty)?, b.ty_in(lookup, buf_ty)?) {
                        (Ty::F32, _) | (_, Ty::F32) => Some(Ty::F32),
                        _ => Some(Ty::I32),
                    }
                }
            }
            Expr::Un(op, a) => match op {
                UnOp::Not | UnOp::FToI => Some(Ty::I32),
                UnOp::IToF | UnOp::Sqrt | UnOp::Exp => Some(Ty::F32),
                UnOp::Neg | UnOp::Abs => a.ty_in(lookup, buf_ty),
            },
            Expr::Select(_, t, _) => t.ty_in(lookup, buf_ty),
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&crate::ir::pretty::expr_to_string(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &str) -> Expr {
        Expr::Var(s.into())
    }

    #[test]
    fn load_detection() {
        let e = Expr::Bin(
            BinOp::Add,
            Box::new(v("x")),
            Box::new(Expr::Load { buf: "a".into(), idx: Box::new(v("i")) }),
        );
        assert!(e.has_load());
        assert_eq!(e.load_count(), 1);
        assert!(!v("x").has_load());
    }

    #[test]
    fn nested_load_count() {
        // a[b[i]] has two loads
        let inner = Expr::Load { buf: "b".into(), idx: Box::new(v("i")) };
        let outer = Expr::Load { buf: "a".into(), idx: Box::new(inner) };
        assert_eq!(outer.load_count(), 2);
    }

    #[test]
    fn subst() {
        let e = Expr::Bin(BinOp::Mul, Box::new(v("i")), Box::new(Expr::I(4)));
        let s = e.subst_var("i", &Expr::I(7));
        assert_eq!(
            Expr::eval_bin(BinOp::Mul, Val::I(7), Val::I(4)),
            Val::I(28)
        );
        assert_eq!(s, Expr::Bin(BinOp::Mul, Box::new(Expr::I(7)), Box::new(Expr::I(4))));
    }

    #[test]
    fn int_float_promotion() {
        assert_eq!(Expr::eval_bin(BinOp::Add, Val::I(1), Val::F(2.5)), Val::F(3.5));
        assert_eq!(Expr::eval_bin(BinOp::Div, Val::I(7), Val::I(2)), Val::I(3));
        assert_eq!(Expr::eval_bin(BinOp::Div, Val::I(1), Val::I(0)), Val::I(0));
    }

    #[test]
    fn cmp_yields_int() {
        assert_eq!(Expr::eval_bin(BinOp::Lt, Val::F(1.0), Val::F(2.0)), Val::I(1));
        assert_eq!(Expr::eval_bin(BinOp::Eq, Val::I(3), Val::I(4)), Val::I(0));
    }
}
