//! Ergonomic construction DSL for IR kernels.
//!
//! Expressions compose with `std::ops` operators and fluent comparison
//! methods; statements are free functions; loop ids are assigned in a
//! deterministic pre-order pass when the kernel is finished, so builders
//! never thread a counter.
//!
//! ```no_run
//! # // no_run: doctest binaries miss the xla rpath in this offline image
//! use pipefwd::ir::build::*;
//! use pipefwd::ir::{Ty, KernelKind};
//!
//! let k = KernelBuilder::new("saxpy", KernelKind::SingleWorkItem)
//!     .buf_ro("x", Ty::F32)
//!     .buf_ro("y", Ty::F32)
//!     .buf_wo("out", Ty::F32)
//!     .scalar("n", Ty::I32)
//!     .scalar_f("a", Ty::F32)
//!     .body(vec![for_(
//!         "i",
//!         i(0),
//!         p("n"),
//!         vec![store("out", v("i"), p("a") * ld("x", v("i")) + ld("y", v("i")))],
//!     )])
//!     .finish();
//! assert_eq!(k.load_count(), 2);
//! ```

use super::expr::{BinOp, Expr, UnOp};
use super::kernel::{Access, BufParam, Kernel, KernelKind, Role, ScalarParam};
use super::stmt::{LoopId, Stmt};
use super::types::Ty;

// ---------------------------------------------------------------------------
// Expression constructors
// ---------------------------------------------------------------------------

/// Integer literal.
pub fn i(v: i64) -> Expr {
    Expr::I(v)
}

/// Float literal.
pub fn f(v: f32) -> Expr {
    Expr::F(v)
}

/// Local variable reference.
pub fn v(name: &str) -> Expr {
    Expr::Var(name.to_string())
}

/// Scalar parameter reference.
pub fn p(name: &str) -> Expr {
    Expr::Param(name.to_string())
}

/// `get_global_id(0)`.
pub fn gid() -> Expr {
    Expr::GlobalId(0)
}

/// Global memory load `buf[idx]`.
pub fn ld(buf: &str, idx: Expr) -> Expr {
    Expr::Load { buf: buf.to_string(), idx: Box::new(idx) }
}

pub fn itof(e: Expr) -> Expr {
    Expr::Un(UnOp::IToF, Box::new(e))
}

pub fn ftoi(e: Expr) -> Expr {
    Expr::Un(UnOp::FToI, Box::new(e))
}

pub fn sqrt(e: Expr) -> Expr {
    Expr::Un(UnOp::Sqrt, Box::new(e))
}

pub fn exp(e: Expr) -> Expr {
    Expr::Un(UnOp::Exp, Box::new(e))
}

pub fn abs(e: Expr) -> Expr {
    Expr::Un(UnOp::Abs, Box::new(e))
}

pub fn neg(e: Expr) -> Expr {
    Expr::Un(UnOp::Neg, Box::new(e))
}

pub fn not(e: Expr) -> Expr {
    Expr::Un(UnOp::Not, Box::new(e))
}

fn bin(op: BinOp, a: Expr, b: Expr) -> Expr {
    Expr::Bin(op, Box::new(a), Box::new(b))
}

/// Fluent comparison / min-max / logical combinators.
pub trait ExprExt: Sized {
    fn e(self) -> Expr;

    fn lt(self, o: Expr) -> Expr {
        bin(BinOp::Lt, self.e(), o)
    }
    fn le(self, o: Expr) -> Expr {
        bin(BinOp::Le, self.e(), o)
    }
    fn gt(self, o: Expr) -> Expr {
        bin(BinOp::Gt, self.e(), o)
    }
    fn ge(self, o: Expr) -> Expr {
        bin(BinOp::Ge, self.e(), o)
    }
    fn eq_(self, o: Expr) -> Expr {
        bin(BinOp::Eq, self.e(), o)
    }
    fn ne(self, o: Expr) -> Expr {
        bin(BinOp::Ne, self.e(), o)
    }
    fn and(self, o: Expr) -> Expr {
        bin(BinOp::And, self.e(), o)
    }
    fn or(self, o: Expr) -> Expr {
        bin(BinOp::Or, self.e(), o)
    }
    fn min(self, o: Expr) -> Expr {
        bin(BinOp::Min, self.e(), o)
    }
    fn max(self, o: Expr) -> Expr {
        bin(BinOp::Max, self.e(), o)
    }
    /// `self ? t : f`
    fn sel(self, t: Expr, f_: Expr) -> Expr {
        Expr::Select(Box::new(self.e()), Box::new(t), Box::new(f_))
    }
}

impl ExprExt for Expr {
    fn e(self) -> Expr {
        self
    }
}

impl std::ops::Add for Expr {
    type Output = Expr;
    fn add(self, o: Expr) -> Expr {
        bin(BinOp::Add, self, o)
    }
}

impl std::ops::Sub for Expr {
    type Output = Expr;
    fn sub(self, o: Expr) -> Expr {
        bin(BinOp::Sub, self, o)
    }
}

impl std::ops::Mul for Expr {
    type Output = Expr;
    fn mul(self, o: Expr) -> Expr {
        bin(BinOp::Mul, self, o)
    }
}

impl std::ops::Div for Expr {
    type Output = Expr;
    fn div(self, o: Expr) -> Expr {
        bin(BinOp::Div, self, o)
    }
}

impl std::ops::Rem for Expr {
    type Output = Expr;
    fn rem(self, o: Expr) -> Expr {
        bin(BinOp::Rem, self, o)
    }
}

// ---------------------------------------------------------------------------
// Statement constructors
// ---------------------------------------------------------------------------

/// `int var = expr;`
pub fn let_i(var: &str, expr: Expr) -> Stmt {
    Stmt::Let { var: var.to_string(), ty: Ty::I32, expr }
}

/// `float var = expr;`
pub fn let_f(var: &str, expr: Expr) -> Stmt {
    Stmt::Let { var: var.to_string(), ty: Ty::F32, expr }
}

/// `var = expr;`
pub fn assign(var: &str, expr: Expr) -> Stmt {
    Stmt::Assign { var: var.to_string(), expr }
}

/// `buf[idx] = val;`
pub fn store(buf: &str, idx: Expr, val: Expr) -> Stmt {
    Stmt::Store { buf: buf.to_string(), idx, val }
}

/// `if (cond) { then_b }`
pub fn if_(cond: Expr, then_b: Vec<Stmt>) -> Stmt {
    Stmt::If { cond, then_b, else_b: vec![] }
}

/// `if (cond) { then_b } else { else_b }`
pub fn if_else(cond: Expr, then_b: Vec<Stmt>, else_b: Vec<Stmt>) -> Stmt {
    Stmt::If { cond, then_b, else_b }
}

/// `for (int var = lo; var < hi; var++) { body }` — loop id assigned at
/// `KernelBuilder::finish` time.
pub fn for_(var: &str, lo: Expr, hi: Expr, body: Vec<Stmt>) -> Stmt {
    Stmt::For { id: LoopId(u32::MAX), var: var.to_string(), lo, hi, body }
}

/// `write_channel_intel(pipe, val);`
pub fn pwrite(pipe: &str, val: Expr) -> Stmt {
    Stmt::PipeWrite { pipe: pipe.to_string(), val }
}

/// `ty var = read_channel_intel(pipe);`
pub fn pread(var: &str, ty: Ty, pipe: &str) -> Stmt {
    Stmt::PipeRead { var: var.to_string(), ty, pipe: pipe.to_string() }
}

/// Renumber all loop ids in pre-order starting from `*next`.
pub fn assign_loop_ids(body: &mut Vec<Stmt>, next: &mut u32) {
    for s in body {
        match s {
            Stmt::For { id, body, .. } => {
                *id = LoopId(*next);
                *next += 1;
                assign_loop_ids(body, next);
            }
            Stmt::If { then_b, else_b, .. } => {
                assign_loop_ids(then_b, next);
                assign_loop_ids(else_b, next);
            }
            _ => {}
        }
    }
}

// ---------------------------------------------------------------------------
// Kernel builder
// ---------------------------------------------------------------------------

pub struct KernelBuilder {
    name: String,
    kind: KernelKind,
    bufs: Vec<BufParam>,
    scalars: Vec<ScalarParam>,
    body: Vec<Stmt>,
    assume_no_true_mlcd: bool,
}

impl KernelBuilder {
    pub fn new(name: &str, kind: KernelKind) -> Self {
        KernelBuilder {
            name: name.to_string(),
            kind,
            bufs: vec![],
            scalars: vec![],
            body: vec![],
            assume_no_true_mlcd: true,
        }
    }

    pub fn buf_ro(mut self, name: &str, elem: Ty) -> Self {
        self.bufs.push(BufParam { name: name.into(), elem, access: Access::ReadOnly, restrict: false });
        self
    }

    pub fn buf_wo(mut self, name: &str, elem: Ty) -> Self {
        self.bufs.push(BufParam { name: name.into(), elem, access: Access::WriteOnly, restrict: false });
        self
    }

    pub fn buf_rw(mut self, name: &str, elem: Ty) -> Self {
        self.bufs.push(BufParam { name: name.into(), elem, access: Access::ReadWrite, restrict: false });
        self
    }

    pub fn scalar(mut self, name: &str, ty: Ty) -> Self {
        self.scalars.push(ScalarParam { name: name.into(), ty });
        self
    }

    /// Alias of `scalar` that reads better for float constants.
    pub fn scalar_f(self, name: &str, ty: Ty) -> Self {
        self.scalar(name, ty)
    }

    /// Mark that the kernel is *not* guaranteed free of true MLCDs (the
    /// paper's feasibility precondition). NW-before-privatization uses this.
    pub fn no_mlcd_guarantee(mut self) -> Self {
        self.assume_no_true_mlcd = false;
        self
    }

    pub fn body(mut self, body: Vec<Stmt>) -> Self {
        self.body = body;
        self
    }

    pub fn finish(mut self) -> Kernel {
        let mut next = 0;
        assign_loop_ids(&mut self.body, &mut next);
        Kernel {
            name: self.name,
            kind: self.kind,
            role: Role::Original,
            bufs: self.bufs,
            scalars: self.scalars,
            body: self.body,
            assume_no_true_mlcd: self.assume_no_true_mlcd,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_example_builds() {
        let k = KernelBuilder::new("saxpy", KernelKind::SingleWorkItem)
            .buf_ro("x", Ty::F32)
            .buf_ro("y", Ty::F32)
            .buf_wo("out", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar_f("a", Ty::F32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![store("out", v("i"), p("a") * ld("x", v("i")) + ld("y", v("i")))],
            )])
            .finish();
        assert_eq!(k.load_count(), 2);
        assert_eq!(k.store_count(), 1);
        assert_eq!(k.loop_ids(), vec![LoopId(0)]);
    }

    #[test]
    fn loop_ids_preorder_and_unique() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .body(vec![for_(
                "a",
                i(0),
                i(4),
                vec![
                    for_("b", i(0), i(4), vec![]),
                    if_(v("a").lt(i(2)), vec![for_("c", i(0), i(4), vec![])]),
                ],
            )])
            .finish();
        assert_eq!(k.loop_ids(), vec![LoopId(0), LoopId(1), LoopId(2)]);
    }

    #[test]
    fn operators_compose() {
        let e = (v("x") + i(1)) * p("n") - v("y") / i(2);
        assert_eq!(e.load_count(), 0);
        let mut vars = vec![];
        e.vars(&mut vars);
        assert_eq!(vars, vec!["x".to_string(), "y".to_string()]);
    }
}
