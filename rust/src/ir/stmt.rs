//! Structured statements of the kernel IR.
//!
//! The IR is deliberately *structured* (no goto/CFG): the offline-compiler
//! model reasons about loop nests the way Intel's HLS scheduler does, and
//! the paper's transformation steps are all defined on structured code.

use super::expr::Expr;
use super::types::Ty;

/// Stable loop identifier, assigned by the builder, preserved by transforms
/// (replicas get fresh ids). Keys the II/report tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LoopId(pub u32);

#[derive(Debug, Clone, PartialEq)]
pub enum Stmt {
    /// Declare-and-assign a local scalar: `ty var = expr;`
    Let { var: String, ty: Ty, expr: Expr },
    /// Re-assign an existing local: `var = expr;`
    Assign { var: String, expr: Expr },
    /// Global-memory write: `buf[idx] = val;`
    Store { buf: String, idx: Expr, val: Expr },
    /// `if (cond) { then_b } else { else_b }`
    If { cond: Expr, then_b: Vec<Stmt>, else_b: Vec<Stmt> },
    /// `for (int var = lo; var < hi; var++) { body }`
    For { id: LoopId, var: String, lo: Expr, hi: Expr, body: Vec<Stmt> },
    /// Blocking channel write: `write_channel_intel(pipe, val);`
    PipeWrite { pipe: String, val: Expr },
    /// Blocking channel read that *declares* its destination:
    /// `ty var = read_channel_intel(pipe);`
    PipeRead { var: String, ty: Ty, pipe: String },
}

impl Stmt {
    /// Pre-order visit of this statement and all nested statements.
    pub fn visit(&self, f: &mut impl FnMut(&Stmt)) {
        f(self);
        match self {
            Stmt::If { then_b, else_b, .. } => {
                for s in then_b {
                    s.visit(f);
                }
                for s in else_b {
                    s.visit(f);
                }
            }
            Stmt::For { body, .. } => {
                for s in body {
                    s.visit(f);
                }
            }
            _ => {}
        }
    }

    /// Visit every expression in this statement (not recursing into nested
    /// statements).
    pub fn visit_own_exprs(&self, f: &mut impl FnMut(&Expr)) {
        match self {
            Stmt::Let { expr, .. } | Stmt::Assign { expr, .. } => f(expr),
            Stmt::Store { idx, val, .. } => {
                f(idx);
                f(val);
            }
            Stmt::If { cond, .. } => f(cond),
            Stmt::For { lo, hi, .. } => {
                f(lo);
                f(hi);
            }
            Stmt::PipeWrite { val, .. } => f(val),
            Stmt::PipeRead { .. } => {}
        }
    }

    /// Visit every expression in this statement and nested statements.
    pub fn visit_all_exprs(&self, f: &mut impl FnMut(&Expr)) {
        self.visit(&mut |s| s.visit_own_exprs(f));
    }

    /// Count global loads anywhere under this statement.
    pub fn load_count(&self) -> usize {
        let mut n = 0;
        self.visit_all_exprs(&mut |e| n += e.load_count());
        n
    }

    /// Count global stores anywhere under this statement.
    pub fn store_count(&self) -> usize {
        let mut n = 0;
        self.visit(&mut |s| {
            if matches!(s, Stmt::Store { .. }) {
                n += 1;
            }
        });
        n
    }
}

/// Visit every statement in a body, pre-order.
pub fn visit_body(body: &[Stmt], f: &mut impl FnMut(&Stmt)) {
    for s in body {
        s.visit(f);
    }
}

/// Count statements in a body (recursively) — a code-size metric used by the
/// area model and by tests.
pub fn body_len(body: &[Stmt]) -> usize {
    let mut n = 0;
    visit_body(body, &mut |_| n += 1);
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::{BinOp, Expr};

    fn sample() -> Vec<Stmt> {
        vec![
            Stmt::Let { var: "x".into(), ty: Ty::I32, expr: Expr::Load { buf: "a".into(), idx: Box::new(Expr::Var("i".into())) } },
            Stmt::For {
                id: LoopId(0),
                var: "j".into(),
                lo: Expr::I(0),
                hi: Expr::Var("x".into()),
                body: vec![Stmt::Store {
                    buf: "b".into(),
                    idx: Expr::Var("j".into()),
                    val: Expr::Bin(BinOp::Add, Box::new(Expr::Var("j".into())), Box::new(Expr::I(1))),
                }],
            },
        ]
    }

    #[test]
    fn counts() {
        let b = sample();
        assert_eq!(body_len(&b), 3);
        assert_eq!(b.iter().map(|s| s.load_count()).sum::<usize>(), 1);
        assert_eq!(b.iter().map(|s| s.store_count()).sum::<usize>(), 1);
    }

    #[test]
    fn visit_order_is_preorder() {
        let b = sample();
        let mut kinds = vec![];
        visit_body(&b, &mut |s| {
            kinds.push(match s {
                Stmt::Let { .. } => "let",
                Stmt::For { .. } => "for",
                Stmt::Store { .. } => "store",
                _ => "?",
            })
        });
        assert_eq!(kinds, vec!["let", "for", "store"]);
    }
}
