//! Kernels, pipes and programs.

use super::stmt::{LoopId, Stmt};
use super::types::Ty;

/// Buffer access mode declared on a kernel parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Access {
    ReadOnly,
    WriteOnly,
    ReadWrite,
}

/// A `__global` pointer parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct BufParam {
    pub name: String,
    pub elem: Ty,
    pub access: Access,
    /// `restrict` qualifier: the programmer guarantees no aliasing with any
    /// other buffer. Our benchmarks (like the paper's baselines) do not use
    /// it; the conservative-compiler model keys off it.
    pub restrict: bool,
}

/// A scalar (by-value) kernel parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalarParam {
    pub name: String,
    pub ty: Ty,
}

/// NDRange vs single work-item form (§2.1 of the paper).
#[derive(Debug, Clone, PartialEq)]
pub enum KernelKind {
    /// Serial kernel; the host launches exactly one work-item.
    SingleWorkItem,
    /// Data-parallel kernel over a 1-D global range (all the paper's
    /// benchmarks are 1-D or linearized); the body uses `Expr::GlobalId(0)`.
    NDRange,
}

/// Role a kernel plays after the feed-forward split (metadata only; used by
/// the scheduler/report, never by semantics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Untransformed kernel.
    Original,
    /// Producer: issues all global loads, writes pipes.
    Memory,
    /// Consumer: reads pipes, computes, stores.
    Compute,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Kernel {
    pub name: String,
    pub kind: KernelKind,
    pub role: Role,
    pub bufs: Vec<BufParam>,
    pub scalars: Vec<ScalarParam>,
    pub body: Vec<Stmt>,
    /// Programmer guarantee required by the paper's design model: there is
    /// no *true* memory loop-carried dependency in this kernel (§3,
    /// "Limitations"). The feasibility check still rejects syntactically
    /// provable true MLCDs.
    pub assume_no_true_mlcd: bool,
}

impl Kernel {
    pub fn buf(&self, name: &str) -> Option<&BufParam> {
        self.bufs.iter().find(|b| b.name == name)
    }

    pub fn scalar(&self, name: &str) -> Option<&ScalarParam> {
        self.scalars.iter().find(|s| s.name == name)
    }

    /// All loop ids in the kernel, pre-order.
    pub fn loop_ids(&self) -> Vec<LoopId> {
        let mut out = vec![];
        super::stmt::visit_body(&self.body, &mut |s| {
            if let Stmt::For { id, .. } = s {
                out.push(*id);
            }
        });
        out
    }

    /// Largest loop id in use (for allocating fresh ones).
    pub fn max_loop_id(&self) -> u32 {
        self.loop_ids().iter().map(|l| l.0).max().unwrap_or(0)
    }

    pub fn load_count(&self) -> usize {
        self.body.iter().map(|s| s.load_count()).sum()
    }

    pub fn store_count(&self) -> usize {
        self.body.iter().map(|s| s.store_count()).sum()
    }
}

/// An OpenCL 2.0 pipe / Intel channel connecting two kernels.
#[derive(Debug, Clone, PartialEq)]
pub struct PipeDecl {
    pub name: String,
    pub ty: Ty,
    /// Minimum depth requested by the programmer; the offline compiler may
    /// deepen it (§3). Depth 0 is normalized to 1.
    pub depth: usize,
}

/// A device program: kernels plus the pipes that connect them.
///
/// The host side (launch order, convergence loops, buffer setup) lives in
/// Rust workload drivers, exactly like OpenCL host code lives in C.
#[derive(Debug, Clone, PartialEq)]
pub struct Program {
    pub name: String,
    pub kernels: Vec<Kernel>,
    pub pipes: Vec<PipeDecl>,
}

impl Program {
    pub fn single(kernel: Kernel) -> Program {
        Program { name: kernel.name.clone(), kernels: vec![kernel], pipes: vec![] }
    }

    pub fn kernel(&self, name: &str) -> Option<&Kernel> {
        self.kernels.iter().find(|k| k.name == name)
    }

    pub fn kernel_mut(&mut self, name: &str) -> Option<&mut Kernel> {
        self.kernels.iter_mut().find(|k| k.name == name)
    }

    pub fn pipe(&self, name: &str) -> Option<&PipeDecl> {
        self.pipes.iter().find(|p| p.name == name)
    }

    /// Set every pipe's depth (the paper's depth-sweep experiment E4c).
    pub fn with_pipe_depth(mut self, depth: usize) -> Program {
        for p in &mut self.pipes {
            p.depth = depth.max(1);
        }
        self
    }

    /// Total statement count across kernels (code-size metric).
    pub fn size(&self) -> usize {
        self.kernels.iter().map(|k| super::stmt::body_len(&k.body)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::expr::Expr;

    fn k(name: &str) -> Kernel {
        Kernel {
            name: name.into(),
            kind: KernelKind::SingleWorkItem,
            role: Role::Original,
            bufs: vec![],
            scalars: vec![ScalarParam { name: "n".into(), ty: Ty::I32 }],
            body: vec![Stmt::Store { buf: "out".into(), idx: Expr::I(0), val: Expr::I(1) }],
            assume_no_true_mlcd: true,
        }
    }

    #[test]
    fn program_lookup() {
        let mut p = Program::single(k("a"));
        p.kernels.push(k("b"));
        assert!(p.kernel("a").is_some());
        assert!(p.kernel("c").is_none());
        assert_eq!(p.size(), 2);
    }

    #[test]
    fn pipe_depth_normalized() {
        let mut p = Program::single(k("a"));
        p.pipes.push(PipeDecl { name: "c0".into(), ty: Ty::I32, depth: 7 });
        let p = p.with_pipe_depth(0);
        assert_eq!(p.pipe("c0").unwrap().depth, 1);
    }
}
