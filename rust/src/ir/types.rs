//! Scalar types and runtime values for the kernel IR.
//!
//! The paper's kernels use 32-bit ints and floats; we widen ints to i64 at
//! runtime (indices over large buffers) while keeping the *declared* type
//! for area/bandwidth accounting (every element moved over DRAM is 4 bytes).

use std::fmt;

/// Declared element/scalar type.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Ty {
    /// 32-bit integer (runtime-widened to i64).
    I32,
    /// 32-bit float.
    F32,
}

impl Ty {
    /// Size in bytes as seen by the memory system.
    pub fn bytes(self) -> u64 {
        4
    }

    /// OpenCL C spelling (for the pretty printer).
    pub fn c_name(self) -> &'static str {
        match self {
            Ty::I32 => "int",
            Ty::F32 => "float",
        }
    }
}

impl fmt::Display for Ty {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.c_name())
    }
}

/// A runtime value. Comparison/logical operators produce `I(0|1)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Val {
    I(i64),
    F(f32),
}

impl Val {
    pub fn ty(self) -> Ty {
        match self {
            Val::I(_) => Ty::I32,
            Val::F(_) => Ty::F32,
        }
    }

    pub fn as_i(self) -> i64 {
        match self {
            Val::I(v) => v,
            Val::F(v) => v as i64,
        }
    }

    pub fn as_f(self) -> f32 {
        match self {
            Val::I(v) => v as f32,
            Val::F(v) => v,
        }
    }

    pub fn is_true(self) -> bool {
        match self {
            Val::I(v) => v != 0,
            Val::F(v) => v != 0.0,
        }
    }

    /// Default (zero) value of a type.
    pub fn zero(ty: Ty) -> Val {
        match ty {
            Ty::I32 => Val::I(0),
            Ty::F32 => Val::F(0.0),
        }
    }

    /// Bit-encode for storage in an `AtomicU64`-backed buffer.
    pub fn to_bits(self) -> u64 {
        match self {
            Val::I(v) => v as u64,
            Val::F(v) => v.to_bits() as u64,
        }
    }

    /// Decode from buffer bits given the buffer's element type.
    pub fn from_bits(ty: Ty, bits: u64) -> Val {
        match ty {
            Ty::I32 => Val::I(bits as i64),
            Ty::F32 => Val::F(f32::from_bits(bits as u32)),
        }
    }
}

impl fmt::Display for Val {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Val::I(v) => write!(f, "{v}"),
            Val::F(v) => write!(f, "{v}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_roundtrip_int() {
        for v in [-5i64, 0, 1, 1 << 40] {
            assert_eq!(Val::from_bits(Ty::I32, Val::I(v).to_bits()), Val::I(v));
        }
    }

    #[test]
    fn bits_roundtrip_float() {
        for v in [-1.5f32, 0.0, 3.25e10, f32::INFINITY] {
            assert_eq!(Val::from_bits(Ty::F32, Val::F(v).to_bits()), Val::F(v));
        }
    }

    #[test]
    fn truthiness() {
        assert!(Val::I(-3).is_true());
        assert!(!Val::I(0).is_true());
        assert!(Val::F(0.5).is_true());
        assert!(!Val::F(0.0).is_true());
    }

    #[test]
    fn coercions() {
        assert_eq!(Val::F(2.9).as_i(), 2);
        assert_eq!(Val::I(3).as_f(), 3.0);
    }
}
