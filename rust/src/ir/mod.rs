//! Kernel IR: an OpenCL-like structured intermediate representation.
//!
//! This is the substrate the whole system operates on — the paper's
//! transformation recipe (§3) is implemented as passes over this IR
//! (`crate::transform`), the offline-compiler model analyzes it
//! (`crate::analysis`), and the FPGA substrate executes it
//! (`crate::sim`).

pub mod build;
pub mod expr;
pub mod kernel;
pub mod pretty;
pub mod stmt;
pub mod types;
pub mod validate;

pub use expr::{BinOp, Expr, UnOp};
pub use kernel::{Access, BufParam, Kernel, KernelKind, PipeDecl, Program, Role, ScalarParam};
pub use stmt::{LoopId, Stmt};
pub use types::{Ty, Val};
pub use validate::{validate_kernel, validate_program, ValidateError};
