//! OpenCL-flavoured pretty printer.
//!
//! Produces source close to what a programmer following the paper's recipe
//! would write (Intel channel notation: `write_channel_intel` /
//! `read_channel_intel`), used by examples, reports and golden tests.

use super::expr::{BinOp, Expr, UnOp};
use super::kernel::{Access, Kernel, KernelKind, PipeDecl, Program};
use super::stmt::Stmt;

pub fn expr_to_string(e: &Expr) -> String {
    match e {
        Expr::I(v) => v.to_string(),
        Expr::F(v) => {
            if v.fract() == 0.0 && v.abs() < 1e9 {
                format!("{v:.1}f")
            } else {
                format!("{v}f")
            }
        }
        Expr::Var(n) => n.clone(),
        Expr::Param(n) => n.clone(),
        Expr::GlobalId(d) => format!("get_global_id({d})"),
        Expr::Load { buf, idx } => format!("{buf}[{}]", expr_to_string(idx)),
        Expr::Bin(op, a, b) => match op {
            BinOp::Min => format!("min({}, {})", expr_to_string(a), expr_to_string(b)),
            BinOp::Max => format!("max({}, {})", expr_to_string(a), expr_to_string(b)),
            _ => format!("({} {} {})", expr_to_string(a), op.c_symbol(), expr_to_string(b)),
        },
        Expr::Un(op, a) => {
            let inner = expr_to_string(a);
            match op {
                UnOp::Neg => format!("(-{inner})"),
                UnOp::Not => format!("(!{inner})"),
                UnOp::IToF => format!("(float)({inner})"),
                UnOp::FToI => format!("(int)({inner})"),
                UnOp::Sqrt => format!("sqrt({inner})"),
                UnOp::Exp => format!("exp({inner})"),
                UnOp::Abs => format!("fabs({inner})"),
            }
        }
        Expr::Select(c, t, f) => format!(
            "({} ? {} : {})",
            expr_to_string(c),
            expr_to_string(t),
            expr_to_string(f)
        ),
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

fn stmt_to_string(s: &Stmt, out: &mut String, depth: usize) {
    match s {
        Stmt::Let { var, ty, expr } => {
            indent(out, depth);
            out.push_str(&format!("{} {} = {};\n", ty.c_name(), var, expr_to_string(expr)));
        }
        Stmt::Assign { var, expr } => {
            indent(out, depth);
            out.push_str(&format!("{} = {};\n", var, expr_to_string(expr)));
        }
        Stmt::Store { buf, idx, val } => {
            indent(out, depth);
            out.push_str(&format!("{}[{}] = {};\n", buf, expr_to_string(idx), expr_to_string(val)));
        }
        Stmt::If { cond, then_b, else_b } => {
            indent(out, depth);
            out.push_str(&format!("if ({}) {{\n", expr_to_string(cond)));
            for st in then_b {
                stmt_to_string(st, out, depth + 1);
            }
            if !else_b.is_empty() {
                indent(out, depth);
                out.push_str("} else {\n");
                for st in else_b {
                    stmt_to_string(st, out, depth + 1);
                }
            }
            indent(out, depth);
            out.push_str("}\n");
        }
        Stmt::For { var, lo, hi, body, .. } => {
            indent(out, depth);
            out.push_str(&format!(
                "for (int {v} = {lo}; {v} < {hi}; {v}++) {{\n",
                v = var,
                lo = expr_to_string(lo),
                hi = expr_to_string(hi)
            ));
            for st in body {
                stmt_to_string(st, out, depth + 1);
            }
            indent(out, depth);
            out.push_str("}\n");
        }
        Stmt::PipeWrite { pipe, val } => {
            indent(out, depth);
            out.push_str(&format!("write_channel_intel({}, {});\n", pipe, expr_to_string(val)));
        }
        Stmt::PipeRead { var, ty, pipe } => {
            indent(out, depth);
            out.push_str(&format!("{} {} = read_channel_intel({});\n", ty.c_name(), var, pipe));
        }
    }
}

pub fn kernel_to_string(k: &Kernel) -> String {
    let mut out = String::new();
    match k.kind {
        KernelKind::SingleWorkItem => {
            out.push_str("__attribute__((max_global_work_dim(0)))\n");
        }
        KernelKind::NDRange => {}
    }
    out.push_str(&format!("__kernel void {}(", k.name));
    let mut params: Vec<String> = vec![];
    for b in &k.bufs {
        let access = match b.access {
            Access::ReadOnly => "const ",
            _ => "",
        };
        let restrict = if b.restrict { " restrict" } else { "" };
        params.push(format!("__global {access}{}*{restrict} {}", b.elem.c_name(), b.name));
    }
    for sp in &k.scalars {
        params.push(format!("{} {}", sp.ty.c_name(), sp.name));
    }
    out.push_str(&params.join(", "));
    out.push_str(") {\n");
    for s in &k.body {
        stmt_to_string(s, &mut out, 1);
    }
    out.push_str("}\n");
    out
}

fn pipe_to_string(p: &PipeDecl) -> String {
    format!(
        "channel {} {} __attribute__((depth({})));\n",
        p.ty.c_name(),
        p.name,
        p.depth
    )
}

pub fn program_to_string(prog: &Program) -> String {
    let mut out = String::new();
    out.push_str(&format!("// program: {}\n", prog.name));
    if !prog.pipes.is_empty() {
        out.push_str("#pragma OPENCL EXTENSION cl_intel_channels : enable\n");
        for p in &prog.pipes {
            out.push_str(&pipe_to_string(p));
        }
        out.push('\n');
    }
    for (idx, k) in prog.kernels.iter().enumerate() {
        if idx > 0 {
            out.push('\n');
        }
        out.push_str(&kernel_to_string(k));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::build::*;
    use crate::ir::{KernelKind, Ty};

    #[test]
    fn prints_opencl_like_source() {
        let k = KernelBuilder::new("k", KernelKind::SingleWorkItem)
            .buf_ro("a", Ty::F32)
            .buf_wo("o", Ty::F32)
            .scalar("n", Ty::I32)
            .body(vec![for_(
                "i",
                i(0),
                p("n"),
                vec![
                    let_f("x", ld("a", v("i"))),
                    if_(v("x").gt(f(0.0)), vec![store("o", v("i"), v("x") * f(2.0))]),
                ],
            )])
            .finish();
        let s = kernel_to_string(&k);
        assert!(s.contains("__kernel void k(__global const float* a, __global float* o, int n)"));
        assert!(s.contains("for (int i = 0; i < n; i++)"));
        assert!(s.contains("float x = a[i];"));
        assert!(s.contains("o[i] = (x * 2.0f);"));
    }

    #[test]
    fn prints_channels() {
        let mut prog = crate::ir::Program::single(
            KernelBuilder::new("m", KernelKind::SingleWorkItem)
                .body(vec![pwrite("c0", i(1))])
                .finish(),
        );
        prog.pipes.push(crate::ir::PipeDecl { name: "c0".into(), ty: Ty::I32, depth: 4 });
        let s = program_to_string(&prog);
        assert!(s.contains("channel int c0 __attribute__((depth(4)));"));
        assert!(s.contains("write_channel_intel(c0, 1);"));
    }
}
