//! Synthetic dataset generators standing in for the paper's datasets
//! (Table 1): random/power-law CSR graphs for the Pannotia benchmarks
//! (G3_circuit, 2M-node BFS graphs), 2D/3D grids for Hotspot, random
//! points for KNN, random weight matrices for BackProp.

use crate::util::rng::Rng;

/// A CSR graph with sorted adjacency lists.
#[derive(Debug, Clone)]
pub struct CsrGraph {
    pub n: usize,
    pub row: Vec<i64>, // n+1 entries
    pub col: Vec<i64>,
}

impl CsrGraph {
    pub fn edges(&self) -> usize {
        self.col.len()
    }

    pub fn degree(&self, v: usize) -> usize {
        (self.row[v + 1] - self.row[v]) as usize
    }

    pub fn neighbors(&self, v: usize) -> &[i64] {
        &self.col[self.row[v] as usize..self.row[v + 1] as usize]
    }
}

/// Uniform random undirected graph with expected average degree `deg`,
/// plus a ring backbone so the graph is connected (BFS from node 0 must
/// reach everything). Sorted neighbor lists give CSR col arrays the
/// partial locality real graph datasets exhibit.
pub fn random_graph(n: usize, deg: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let m = n * deg.saturating_sub(2) / 2;
    let mut adj: Vec<Vec<i64>> = vec![vec![]; n];
    for v in 0..n {
        let u = (v + 1) % n;
        adj[v].push(u as i64);
        adj[u].push(v as i64);
    }
    for _ in 0..m {
        let a = rng.below(n as u64) as usize;
        let b = rng.below(n as u64) as usize;
        if a != b {
            adj[a].push(b as i64);
            adj[b].push(a as i64);
        }
    }
    build_csr(n, adj)
}

/// Circuit-like graph (G3_circuit stand-in): mostly short-range mesh
/// neighbours plus a few long-range nets — near-regular degree, moderate
/// locality, like a circuit netlist.
pub fn circuit_graph(n: usize, deg: usize, seed: u64) -> CsrGraph {
    let mut rng = Rng::new(seed);
    let mut adj: Vec<Vec<i64>> = vec![vec![]; n];
    for v in 0..n {
        let local = deg.saturating_sub(1).max(1);
        for _ in 0..local / 2 {
            // short-range net: a few rows away (2..64) — circuit netlists
            // are local but not contiguous, so gathers stay irregular
            let off = rng.range(2, 64);
            let u = (v as i64 + off).rem_euclid(n as i64) as usize;
            if u != v {
                adj[v].push(u as i64);
                adj[u].push(v as i64);
            }
        }
        if rng.chance(0.25) {
            // occasional long net
            let u = rng.below(n as u64) as usize;
            if u != v {
                adj[v].push(u as i64);
                adj[u].push(v as i64);
            }
        }
    }
    build_csr(n, adj)
}

fn build_csr(n: usize, mut adj: Vec<Vec<i64>>) -> CsrGraph {
    let mut row = Vec::with_capacity(n + 1);
    let mut col = vec![];
    row.push(0i64);
    for a in adj.iter_mut() {
        a.sort_unstable();
        a.dedup();
        col.extend_from_slice(a);
        row.push(col.len() as i64);
    }
    CsrGraph { n, row, col }
}

/// Random node values in (0, 1) — the MIS/Color priority values.
pub fn node_values(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    // strictly distinct values so greedy MIS/Color tie-breaks are stable
    let mut v: Vec<f32> = (0..n).map(|i| (i as f32 + 0.5) / n as f32).collect();
    rng.shuffle(&mut v);
    v
}

/// Hotspot-style 2D grids: temperatures around ambient, power in [0,1).
pub fn hotspot_grids(rows: usize, cols: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let temp: Vec<f32> = (0..rows * cols).map(|_| rng.f32_range(50.0, 90.0)).collect();
    let power: Vec<f32> = (0..rows * cols).map(|_| rng.f32()).collect();
    (temp, power)
}

/// Random non-negative distance matrix with zero diagonal (FW input).
pub fn distance_matrix(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut d = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..n {
            if i != j {
                d[i * n + j] = rng.f32_range(1.0, 100.0);
            }
        }
    }
    d
}

/// Random f32 matrix with entries in [-s, s).
pub fn matrix(rows: usize, cols: usize, s: f32, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..rows * cols).map(|_| rng.f32_range(-s, s)).collect()
}

/// NW-style random sequence-similarity scores in [-4, 5).
pub fn nw_scores(n: usize, seed: u64) -> Vec<i64> {
    let mut rng = Rng::new(seed);
    (0..n * n).map(|_| rng.range(-4, 5)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_well_formed() {
        let g = random_graph(1000, 8, 1);
        assert_eq!(g.row.len(), 1001);
        assert_eq!(*g.row.last().unwrap() as usize, g.col.len());
        let avg = g.edges() as f64 / g.n as f64;
        assert!(avg > 4.0 && avg < 10.0, "avg degree {avg}");
        for v in 0..g.n {
            let nb = g.neighbors(v);
            for w in nb.windows(2) {
                assert!(w[0] < w[1], "sorted+dedup");
            }
        }
    }

    #[test]
    fn circuit_graph_has_locality() {
        let g = circuit_graph(10_000, 12, 2);
        let mut near = 0usize;
        let mut total = 0usize;
        for v in 0..g.n {
            for &u in g.neighbors(v) {
                total += 1;
                if (u - v as i64).abs() <= 64 {
                    near += 1;
                }
            }
        }
        assert!(near as f64 / total as f64 > 0.5, "local fraction");
    }

    #[test]
    fn distance_matrix_zero_diag() {
        let d = distance_matrix(16, 3);
        for i in 0..16 {
            assert_eq!(d[i * 16 + i], 0.0);
        }
    }

    #[test]
    fn node_values_distinct() {
        let v = node_values(1000, 4);
        let mut s = v.clone();
        s.sort_by(f32::total_cmp);
        s.dedup();
        assert_eq!(s.len(), 1000);
    }
}
