//! BackProp (Rodinia, Table 2: 44.54x; in-text: weight-adjust loop II=416).
//!
//! One training step of a 1-hidden-layer MLP on a single sample:
//!  * `backprop_fwd` — hidden-layer forward pass over transposed weights
//!    (sequential streams + a DLCD sum reduction);
//!  * `backprop_adjust` — the dominant kernel: momentum weight update that
//!    loads *and* stores `w` and `oldw` in the same loop. Two serialized
//!    buffers push the conservative II into the low 400s, matching the
//!    paper's 416; the feed-forward split streams both at II=1.
//!
//! The output-layer delta is computed host-side (as Rodinia's host code
//! does between the two kernel launches).

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen;

pub struct BackProp;

pub const SEED: u64 = 0xBACC;
pub const LR: f32 = 0.3;
pub const MOM: f32 = 0.3;

pub fn dims(scale: Scale) -> (usize, usize) {
    // (n_in, n_hid)
    match scale {
        Scale::Tiny => (64, 16),
        Scale::Small => (8192, 16),
        Scale::Paper => (512 * 1024, 16),
    }
}

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

pub struct Ref {
    pub hidden: Vec<f32>,
    pub w: Vec<f32>,
    pub oldw: Vec<f32>,
}

/// Native reference for one step (same arithmetic order).
pub fn reference(scale: Scale) -> Ref {
    let (n_in, n_hid) = dims(scale);
    let x = datagen::matrix(n_in, 1, 1.0, SEED);
    let wt = datagen::matrix(n_hid, n_in, 0.1, SEED ^ 2); // transposed: [hid][in]
    let mut w = datagen::matrix(n_in, n_hid, 0.1, SEED ^ 3); // [in][hid]
    let mut oldw = vec![0.0f32; n_in * n_hid];
    let delta = datagen::matrix(n_hid, 1, 0.2, SEED ^ 4);

    let mut hidden = vec![0.0f32; n_hid];
    for j in 0..n_hid {
        let mut sum = 0.0f32;
        for i in 0..n_in {
            sum += x[i] * wt[j * n_in + i];
        }
        hidden[j] = sigmoid(sum);
    }
    for i in 0..n_in {
        for j in 0..n_hid {
            let idx = i * n_hid + j;
            let dw = LR * delta[j] * x[i] + MOM * oldw[idx];
            w[idx] += dw;
            oldw[idx] = dw;
        }
    }
    Ref { hidden, w, oldw }
}

impl Workload for BackProp {
    fn name(&self) -> &'static str {
        "backprop"
    }

    fn suite(&self) -> &'static str {
        "Rodinia"
    }

    fn dwarf(&self) -> &'static str {
        "Unstructured Grid"
    }

    fn pattern(&self) -> &'static str {
        "Regular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        let (n_in, n_hid) = dims(scale);
        format!("{n_in}x{n_hid} layer, 1 training step")
    }

    fn dominant(&self) -> &'static str {
        "backprop_adjust"
    }

    fn kernels(&self) -> Vec<Kernel> {
        // Forward pass with the MAC loop unrolled 16x (as Rodinia's OpenCL
        // port unrolls its reduction): the fadd recurrence then bounds the
        // *unrolled* iteration, i.e. ~13/16 cycles per element instead of 13.
        const UNROLL: i64 = 16;
        let mut macs: Vec<crate::ir::Stmt> = vec![];
        for u in 0..UNROLL {
            let idx = v("i16") * i(UNROLL) + i(u);
            macs.push(assign(
                "sum",
                v("sum") + ld("x", idx.clone()) * ld("wt", v("j3") * p("n_in") + idx),
            ));
        }
        let fwd = KernelBuilder::new("backprop_fwd", KernelKind::SingleWorkItem)
            .buf_ro("x", Ty::F32)
            .buf_ro("wt", Ty::F32)
            .buf_wo("hidden", Ty::F32)
            .scalar("n_in", Ty::I32)
            .scalar("n_hid", Ty::I32)
            .body(vec![for_(
                "j3",
                i(0),
                p("n_hid"),
                vec![
                    let_f("sum", f(0.0)),
                    for_("i16", i(0), p("n_in") / i(UNROLL), macs.clone()),
                    store("hidden", v("j3"), f(1.0) / (f(1.0) + exp(neg(v("sum"))))),
                ],
            )])
            .finish();

        let adjust = KernelBuilder::new("backprop_adjust", KernelKind::SingleWorkItem)
            .buf_ro("x", Ty::F32)
            .buf_ro("delta", Ty::F32)
            .buf_rw("w", Ty::F32)
            .buf_rw("oldw", Ty::F32)
            .scalar("n_in", Ty::I32)
            .scalar("n_hid", Ty::I32)
            .scalar_f("lr", Ty::F32)
            .scalar_f("mom", Ty::F32)
            .body(vec![for_(
                "i3",
                i(0),
                p("n_in"),
                vec![for_(
                    "j3",
                    i(0),
                    p("n_hid"),
                    vec![
                        let_i("idx", v("i3") * p("n_hid") + v("j3")),
                        let_f(
                            "dw",
                            p("lr") * ld("delta", v("j3")) * ld("x", v("i3"))
                                + p("mom") * ld("oldw", v("idx")),
                        ),
                        store("w", v("idx"), ld("w", v("idx")) + v("dw")),
                        store("oldw", v("idx"), v("dw")),
                    ],
                )],
            )])
            .finish();

        vec![fwd, adjust]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let (n_in, n_hid) = dims(scale);
        let mut m = MemoryImage::new();
        m.add_f32s("x", &datagen::matrix(n_in, 1, 1.0, SEED))
            .add_f32s("wt", &datagen::matrix(n_hid, n_in, 0.1, SEED ^ 2))
            .add_f32s("w", &datagen::matrix(n_in, n_hid, 0.1, SEED ^ 3))
            .add_zeros("oldw", Ty::F32, n_in * n_hid)
            .add_f32s("delta", &datagen::matrix(n_hid, 1, 0.2, SEED ^ 4))
            .add_zeros("hidden", Ty::F32, n_hid);
        m.set_i("n_in", n_in as i64)
            .set_i("n_hid", n_hid as i64)
            .set_f("lr", LR)
            .set_f("mom", MOM);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        h.launch(app.unit("backprop_fwd"), img)?;
        // host computes the output-layer delta between launches (Rodinia
        // does this on the CPU too); ours is pre-seeded in the image.
        let _ = img.scalar("lr");
        h.launch(app.unit("backprop_adjust"), img)?;
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let want = reference(scale);
        let hid = img.buf("hidden").unwrap().to_f32s();
        for (ix, (g, w)) in hid.iter().zip(&want.hidden).enumerate() {
            if (g - w).abs() > 1e-4 {
                return Err(format!("backprop: hidden[{ix}] = {g}, want {w}"));
            }
        }
        let w_ = img.buf("w").unwrap().to_f32s();
        for (ix, (g, w)) in w_.iter().zip(&want.w).enumerate() {
            if (g - w).abs() > 1e-5 {
                return Err(format!("backprop: w[{ix}] = {g}, want {w}"));
            }
        }
        let ow = img.buf("oldw").unwrap().to_f32s();
        for (ix, (g, w)) in ow.iter().zip(&want.oldw).enumerate() {
            if (g - w).abs() > 1e-5 {
                return Err(format!("backprop: oldw[{ix}] = {g}, want {w}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn adjust_ii_in_paper_band() {
        let ks = BackProp.kernels();
        let rep = crate::analysis::report::KernelReport::for_kernel(&ks[1]);
        let ii = rep.max_ii();
        assert!((380..=470).contains(&ii), "adjust ii = {ii} (paper: 416)");
        // serialized on both w and oldw, attached to the inner loop
        let ser = rep.loops.iter().find(|l| l.serialized_by.is_some()).unwrap();
        assert_eq!(ser.depth, 1);
    }

    #[test]
    fn tiny_baseline_validates() {
        let cfg = DeviceConfig::pac_a10();
        run_workload(&BackProp, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
    }

    #[test]
    fn tiny_ff_validates_with_big_speedup() {
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&BackProp, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff =
            run_workload(&BackProp, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 10.0, "backprop tiny ff speedup = {speedup}");
    }
}
