//! Auto-generated microbenchmarks (§4.2, Table 3) plus the parametrized
//! family the paper's future-work section calls for.
//!
//! The first set targets the memory access pattern: 8 load streams x
//! arithmetic intensity 10, regular (`M_AI10_R`) vs irregular
//! (`M_AI10_IR`). The second set adds main-loop divergence (a data-
//! dependent inner `for` with an `if`) and a DLCD reduction at arithmetic
//! intensity 6 (`M_AI6_forif_R` / `M_AI6_forif_IR`).

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Stmt, Ty};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::util::rng::Rng;

pub const SEED: u64 = 0x111C40;
pub const N_STREAMS: usize = 8;

/// Generator parameters (the paper's two axes plus arithmetic intensity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MicroSpec {
    /// Arithmetic ops per loaded word.
    pub arith_intensity: usize,
    /// Irregular (index-buffer-driven) vs sequential loads.
    pub irregular: bool,
    /// Add the divergent inner for/if with a DLCD reduction.
    pub divergent: bool,
}

impl MicroSpec {
    pub fn label(&self) -> String {
        format!(
            "M_AI{}{}{}",
            self.arith_intensity,
            if self.divergent { "_forif" } else { "" },
            if self.irregular { "_IR" } else { "_R" }
        )
    }

    /// The paper's four Table-3 microbenchmarks.
    pub fn table3() -> Vec<MicroSpec> {
        vec![
            MicroSpec { arith_intensity: 10, irregular: false, divergent: false },
            MicroSpec { arith_intensity: 10, irregular: true, divergent: false },
            MicroSpec { arith_intensity: 6, irregular: false, divergent: true },
            MicroSpec { arith_intensity: 6, irregular: true, divergent: true },
        ]
    }

    /// The extended family (future work): AI x pattern x divergence sweep.
    pub fn family() -> Vec<MicroSpec> {
        let mut out = vec![];
        for ai in [2, 6, 10, 20] {
            for irregular in [false, true] {
                for divergent in [false, true] {
                    out.push(MicroSpec { arith_intensity: ai, irregular, divergent });
                }
            }
        }
        out
    }
}

/// A generated microbenchmark.
pub struct Micro {
    pub spec: MicroSpec,
    label: &'static str,
}

impl Micro {
    pub fn new(spec: MicroSpec) -> Micro {
        // leak the label: Workload::name returns &'static str
        let label: &'static str = Box::leak(spec.label().into_boxed_str());
        Micro { spec, label }
    }
}

pub fn elements(scale: Scale) -> usize {
    match scale {
        Scale::Tiny => 2_048,
        Scale::Small => 100_000,
        Scale::Paper => 2_000_000,
    }
}

/// Build the generated kernel for a spec.
pub fn generate_kernel(spec: MicroSpec) -> Kernel {
    let mut body: Vec<Stmt> = vec![];
    // Loads: 8 streams, either a[t] or a[perm[t]].
    for s in 0..N_STREAMS {
        let idx = if spec.irregular {
            ld("perm", v("t2"))
        } else {
            v("t2")
        };
        body.push(let_f(&format!("x{s}"), ld(&format!("a{s}"), idx)));
    }
    // Arithmetic: AI ops per load, a chain mixing mul/add over the streams.
    let total_ops = spec.arith_intensity * N_STREAMS;
    body.push(let_f("acc", v("x0")));
    for op in 0..total_ops {
        let src = format!("x{}", op % N_STREAMS);
        if op % 3 == 0 {
            body.push(assign("acc", v("acc") * f(1.0001) + v(&src)));
        } else if op % 3 == 1 {
            body.push(assign("acc", v("acc") + v(&src) * f(0.5)));
        } else {
            body.push(assign("acc", v("acc").max(v(&src) - f(0.25))));
        }
    }
    if spec.divergent {
        // Divergence: data-dependent trip count + if, with a reduction
        // carried across the inner loop (the DLCD of Fig. 3b).
        body.push(let_i("trip", ld("trips", v("t2"))));
        body.push(let_f("r", f(0.0)));
        body.push(for_(
            "it",
            i(0),
            v("trip"),
            vec![if_(
                (v("it") % i(2)).eq_(i(0)),
                // leaky-integrator recurrence: the carried value feeds a
                // multiply, so no hard-FP accumulator shortcut applies —
                // a true Fig. 3b DLCD with a multi-cycle chain
                vec![assign("r", v("r") * f(0.9995) + v("acc") * f(0.125))],
            )],
        ));
        body.push(assign("acc", v("acc") + v("r")));
    }
    body.push(store("out", v("t2"), v("acc")));

    let mut kb = KernelBuilder::new(&format!("micro_{}", spec.label().to_lowercase()), KernelKind::SingleWorkItem);
    for s in 0..N_STREAMS {
        kb = kb.buf_ro(&format!("a{s}"), Ty::F32);
    }
    if spec.irregular {
        kb = kb.buf_ro("perm", Ty::I32);
    }
    if spec.divergent {
        kb = kb.buf_ro("trips", Ty::I32);
    }
    kb.buf_wo("out", Ty::F32)
        .scalar("n", Ty::I32)
        .body(vec![for_("t2", i(0), p("n"), body)])
        .finish()
}

/// Native reference evaluation.
pub fn reference(spec: MicroSpec, n: usize) -> Vec<f32> {
    let streams = gen_streams(n);
    let perm = gen_perm(n);
    let trips = gen_trips(n);
    (0..n)
        .map(|t| {
            let src = if spec.irregular { perm[t] as usize } else { t };
            let x: Vec<f32> = (0..N_STREAMS).map(|s| streams[s][src]).collect();
            let mut acc = x[0];
            for op in 0..spec.arith_intensity * N_STREAMS {
                let v = x[op % N_STREAMS];
                if op % 3 == 0 {
                    acc = acc * 1.0001 + v;
                } else if op % 3 == 1 {
                    acc += v * 0.5;
                } else {
                    acc = acc.max(v - 0.25);
                }
            }
            if spec.divergent {
                let mut r = 0.0f32;
                for it in 0..trips[t] {
                    if it % 2 == 0 {
                        r = r * 0.9995 + acc * 0.125;
                    }
                }
                acc += r;
            }
            acc
        })
        .collect()
}

fn gen_streams(n: usize) -> Vec<Vec<f32>> {
    (0..N_STREAMS)
        .map(|s| {
            let mut rng = Rng::new(SEED + s as u64);
            (0..n).map(|_| rng.f32_range(-1.0, 1.0)).collect()
        })
        .collect()
}

fn gen_perm(n: usize) -> Vec<i64> {
    Rng::new(SEED ^ 0xFF).permutation(n)
}

fn gen_trips(n: usize) -> Vec<i64> {
    let mut rng = Rng::new(SEED ^ 0xAB);
    (0..n).map(|_| rng.range(1, 9)).collect()
}

impl Workload for Micro {
    fn name(&self) -> &'static str {
        self.label
    }

    fn suite(&self) -> &'static str {
        "Micro"
    }

    fn dwarf(&self) -> &'static str {
        "Synthetic"
    }

    fn pattern(&self) -> &'static str {
        if self.spec.irregular {
            "Irregular"
        } else {
            "Regular"
        }
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        format!("{} elements x {N_STREAMS} streams", elements(scale))
    }

    fn dominant(&self) -> &'static str {
        // single-kernel: dominant is itself; name is dynamic, so resolve
        // via kernels()[0] in build().
        self.label
    }

    fn build(&self, variant: crate::transform::Variant) -> Result<App, crate::transform::FeasibilityError> {
        let k = generate_kernel(self.spec);
        let dominant = k.name.clone();
        super::assemble(self.label, &[k], &dominant, &[], variant)
    }

    fn kernels(&self) -> Vec<Kernel> {
        vec![generate_kernel(self.spec)]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let n = elements(scale);
        let streams = gen_streams(n);
        let mut m = MemoryImage::new();
        for (s, data) in streams.iter().enumerate() {
            m.add_f32s(&format!("a{s}"), data);
        }
        if self.spec.irregular {
            m.add_i64s("perm", &gen_perm(n));
        }
        if self.spec.divergent {
            m.add_i64s("trips", &gen_trips(n));
        }
        m.add_zeros("out", Ty::F32, n);
        m.set_i("n", n as i64);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        let unit = &app.units[0];
        h.launch(unit, img)
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let n = elements(scale);
        let want = reference(self.spec, n);
        let got = img.buf("out").unwrap().to_f32s();
        for (ix, (g, w)) in got.iter().zip(&want).enumerate() {
            if (g - w).abs() > 1e-4 * (1.0 + w.abs()) {
                return Err(format!("{}: out[{ix}] = {g}, want {w}", self.label));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn labels_match_paper() {
        let names: Vec<String> = MicroSpec::table3().iter().map(|s| s.label()).collect();
        assert_eq!(names, vec!["M_AI10_R", "M_AI10_IR", "M_AI6_forif_R", "M_AI6_forif_IR"]);
    }

    #[test]
    fn generated_kernels_validate() {
        for spec in MicroSpec::table3() {
            let k = generate_kernel(spec);
            assert_eq!(crate::ir::validate_kernel(&k), Ok(()), "{}", spec.label());
        }
    }

    #[test]
    fn divergent_kernels_have_dlcd() {
        let k = generate_kernel(MicroSpec { arith_intensity: 6, irregular: false, divergent: true });
        let lcd = crate::analysis::analyze_lcd(&k);
        assert!(lcd.dlcds.iter().any(|d| d.var == "r"));
        assert!(lcd.mlcds.is_empty());
    }

    #[test]
    fn tiny_all_four_validate_under_m2c2() {
        let cfg = DeviceConfig::pac_a10();
        for spec in MicroSpec::table3() {
            let w = Micro::new(spec);
            run_workload(&w, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
            run_workload(&w, Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny, &cfg).unwrap();
        }
    }
}
