//! The paper's benchmark suite (Table 1) re-implemented on the kernel IR:
//! Rodinia (BFS is Pannotia's formulation, Hotspot, Hotspot3D, KNN, NW,
//! BackProp) and Pannotia (FW, MIS, Graph Coloring, PageRank), plus the
//! §4.2 auto-generated microbenchmarks.
//!
//! Each workload supplies its baseline single work-item kernels, a dataset
//! generator (`Scale`d down from the paper's sizes — see DESIGN.md
//! substitution table), a host driver (convergence loops, ping-pong buffer
//! swaps — the OpenCL host-code role), and a validator against a native
//! Rust reference implementation.

pub mod backprop;
pub mod bfs;
pub mod color;
pub mod datagen;
pub mod fw;
pub mod hotspot;
pub mod hotspot3d;
pub mod knn;
pub mod micro;
pub mod mis;
pub mod nw;
pub mod pagerank;

use crate::analysis::AreaEstimate;
use crate::ir::{Access, Kernel, Program};
use crate::sim::device::DeviceConfig;
use crate::sim::exec::{run_group, ExecError, ExecOptions};
use crate::sim::mem::MemoryImage;
use crate::sim::perf::{LaunchMetrics, PerfModel};
use crate::sim::profile::KernelProfile;
use crate::transform::{
    feedforward, privatize, replicate, replicate_1p, vectorize, FeasibilityError, Variant,
};
use crate::util::json::Json;
use std::collections::HashMap;

/// Prefix distinguishing *result-validation* failures (the computed
/// output diverged from the native reference — an invalid configuration,
/// like NW past its safe pipe depth) from feasibility and execution
/// errors. Depth searches may skip validation-class failures exactly as a
/// paper author drops an invalid configuration; every other error class
/// is a real defect and must propagate.
pub const VALIDATION_PREFIX: &str = "validation: ";

/// Is this stringified cell error a validation-class failure?
pub fn is_validation_error(e: &str) -> bool {
    MeasureError::parse(e).class == ErrorClass::Validation
}

/// Prefix for *feasibility*-class failures (the variant cannot be built
/// for this workload at all — e.g. replication on NW). Applied by
/// `Engine::measure` where the build error is stringified. Searches over
/// a configuration space may skip these like validation failures; they
/// describe the configuration, not a defect.
pub const INFEASIBLE_PREFIX: &str = "infeasible: ";

/// Is this stringified cell error a feasibility-class failure?
pub fn is_infeasible_error(e: &str) -> bool {
    MeasureError::parse(e).class == ErrorClass::Infeasible
}

/// The error classes a measurement can fail with. `Validation` and
/// `Infeasible` describe the *configuration* (searches may skip them);
/// `Other` is a real defect and must propagate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    Validation,
    Infeasible,
    Other,
}

impl ErrorClass {
    /// Wire-protocol label (`pipefwd-api-v1` error documents).
    pub fn label(self) -> &'static str {
        match self {
            ErrorClass::Validation => "validation",
            ErrorClass::Infeasible => "infeasible",
            ErrorClass::Other => "error",
        }
    }

    pub fn parse(s: &str) -> Option<ErrorClass> {
        match s {
            "validation" => Some(ErrorClass::Validation),
            "infeasible" => Some(ErrorClass::Infeasible),
            "error" => Some(ErrorClass::Other),
            _ => None,
        }
    }
}

/// A measurement failure as a typed (class, message) pair — the form the
/// `pipefwd-api-v1` wire protocol transports. The persistent store keeps
/// the historical string form ([`MeasureError::render`]: class prefix +
/// message), so promoting the class to a field changes no store bytes and
/// needs no schema bump; [`MeasureError::parse`] recovers the class from
/// any stored string, treating unprefixed messages as [`ErrorClass::Other`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MeasureError {
    pub class: ErrorClass,
    pub msg: String,
}

impl MeasureError {
    /// Classify a stringified cell error (the store/engine form).
    pub fn parse(e: &str) -> MeasureError {
        if let Some(m) = e.strip_prefix(VALIDATION_PREFIX) {
            MeasureError { class: ErrorClass::Validation, msg: m.to_string() }
        } else if let Some(m) = e.strip_prefix(INFEASIBLE_PREFIX) {
            MeasureError { class: ErrorClass::Infeasible, msg: m.to_string() }
        } else {
            MeasureError { class: ErrorClass::Other, msg: e.to_string() }
        }
    }

    /// The exact store/engine string form: class prefix + message. For
    /// every parsed error, `render(parse(s)) == s` — the byte-stability
    /// the no-schema-bump promise rests on.
    pub fn render(&self) -> String {
        match self.class {
            ErrorClass::Validation => format!("{VALIDATION_PREFIX}{}", self.msg),
            ErrorClass::Infeasible => format!("{INFEASIBLE_PREFIX}{}", self.msg),
            ErrorClass::Other => self.msg.clone(),
        }
    }

    /// The `pipefwd-api-v1` error document: `{"class": ..., "msg": ...}`.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("class".into(), Json::Str(self.class.label().into())),
            ("msg".into(), Json::Str(self.msg.clone())),
        ])
    }

    /// Inverse of [`MeasureError::to_json`].
    pub fn from_json(v: &Json) -> Option<MeasureError> {
        Some(MeasureError {
            class: ErrorClass::parse(v.get("class")?.as_str()?)?,
            msg: v.get("msg")?.as_str()?.to_string(),
        })
    }
}

/// Dataset scale: `Tiny` matches the AOT artifact shapes (PJRT golden
/// validation), `Small` is the default experiment size, `Paper` approaches
/// the paper's dataset sizes (slow under interpretation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Tiny,
    Small,
    Paper,
}

/// A built application: one FPGA design containing several launch units.
#[derive(Debug, Clone)]
pub struct App {
    pub name: String,
    /// Launch units in host-invocation granularity; each unit's kernels
    /// run concurrently (separate queues + pipes).
    pub units: Vec<Program>,
}

impl App {
    /// The union design (all kernels resident on the fabric at once) —
    /// what area/fmax are charged against.
    pub fn union_program(&self) -> Program {
        let mut kernels = vec![];
        let mut pipes = vec![];
        for u in &self.units {
            kernels.extend(u.kernels.iter().cloned());
            pipes.extend(u.pipes.iter().cloned());
        }
        Program { name: self.name.clone(), kernels, pipes }
    }

    pub fn unit(&self, name: &str) -> &Program {
        self.units
            .iter()
            .find(|p| p.name == name)
            .unwrap_or_else(|| panic!("no unit `{name}` in app {}", self.name))
    }
}

/// Assemble an app from baseline kernels under a design variant.
///
/// * `dominant` — the kernel replicated under MxCx/M1Cx (paper step 12:
///   replicate only the execution-time-dominant kernel).
/// * `privatize_first` — kernels that need the NW-style privatization
///   before the feed-forward split is feasible.
pub fn assemble(
    name: &str,
    kernels: &[Kernel],
    dominant: &str,
    privatize_first: &[&str],
    variant: Variant,
) -> Result<App, FeasibilityError> {
    let mut units = vec![];
    for k in kernels {
        let unit = match variant {
            Variant::Baseline => Program::single(k.clone()),
            Variant::FeedForward { depth }
            | Variant::MxCx { depth, .. }
            | Variant::M1Cx { depth, .. }
            | Variant::Vectorized { depth, .. } => {
                let mut kk = k.clone();
                if privatize_first.contains(&k.name.as_str()) {
                    kk = privatize(&kk).expect("privatization applies");
                }
                if let Variant::Vectorized { width, .. } = variant {
                    if k.name == dominant {
                        kk = vectorize(&kk, width);
                        // keep the launch-unit name stable
                        kk.name = k.name.clone();
                    }
                }
                let ff = feedforward(&kk, depth_of(variant).unwrap_or(depth))?;
                match variant {
                    Variant::MxCx { parts, .. } if k.name == dominant => replicate(&ff, parts),
                    Variant::M1Cx { consumers, .. } if k.name == dominant => {
                        replicate_1p(&ff, consumers)
                    }
                    _ => ff,
                }
            }
        };
        let mut unit = unit;
        unit.name = k.name.clone(); // launch units keyed by base kernel name
        units.push(unit);
    }
    Ok(App { name: format!("{name}_{}", variant.label()), units })
}

fn depth_of(v: Variant) -> Option<usize> {
    match v {
        Variant::Baseline => None,
        Variant::FeedForward { depth }
        | Variant::MxCx { depth, .. }
        | Variant::M1Cx { depth, .. }
        | Variant::Vectorized { depth, .. } => Some(depth),
    }
}

// ---------------------------------------------------------------------------
// Execution traces (the two-tier measurement pipeline's first tier)
// ---------------------------------------------------------------------------

/// One host launch as the trace tier records it: which unit ran and the
/// per-kernel profiles the interpreter emitted.
#[derive(Debug, Clone, PartialEq)]
pub struct LaunchRecord {
    pub unit: String,
    pub profiles: Vec<KernelProfile>,
}

/// The full functional execution trace of one workload run: every host
/// launch in order. This is everything the performance models consume —
/// replaying it through [`replay_built_workload`] reproduces the exact
/// `Harness` metrics of the original run without re-interpreting, which
/// is what lets a depth sweep run the interpreter once (the trace is
/// invariant to pipe depth wherever kernels share no writable buffers;
/// see [`unit_depth_invariant`]).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ExecTrace {
    pub launches: Vec<LaunchRecord>,
}

impl ExecTrace {
    /// Serialize for the persistent trace store (canonical field order;
    /// profiles sorted internally by `KernelProfile::to_json`).
    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.launches
                .iter()
                .map(|r| {
                    Json::Obj(vec![
                        ("unit".into(), Json::Str(r.unit.clone())),
                        (
                            "kernels".into(),
                            Json::Arr(r.profiles.iter().map(KernelProfile::to_json).collect()),
                        ),
                    ])
                })
                .collect(),
        )
    }

    /// Inverse of [`ExecTrace::to_json`]; malformed input is `None`.
    pub fn from_json(v: &Json) -> Option<ExecTrace> {
        let launches = v
            .as_array()?
            .iter()
            .map(|r| {
                Some(LaunchRecord {
                    unit: r.get("unit")?.as_str()?.to_string(),
                    profiles: r
                        .get("kernels")?
                        .as_array()?
                        .iter()
                        .map(KernelProfile::from_json)
                        .collect::<Option<Vec<_>>>()?,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        Some(ExecTrace { launches })
    }
}

/// Is this launch unit's functional trace provably invariant to pipe
/// depth? Pipe depth only changes *when* tokens are delivered, never what
/// they carry — the interleaving can leak into results only through a
/// buffer one kernel writes while another kernel of the same concurrent
/// group reads or writes it (NW's split is the canonical counterexample:
/// the memory kernel re-reads rows the compute kernel is still writing,
/// safe only below the row width). Single-kernel units are trivially
/// invariant; multi-kernel units are invariant when every shared buffer
/// is read-only on all sides. Workloads whose shared-buffer races are
/// benign by construction can vouch past this conservative check via
/// [`Workload::benign_cross_kernel_races`].
pub fn unit_depth_invariant(unit: &Program) -> bool {
    for (i, a) in unit.kernels.iter().enumerate() {
        for b in unit.kernels.iter().skip(i + 1) {
            for ba in &a.bufs {
                if let Some(bb) = b.buf(&ba.name) {
                    if ba.access != Access::ReadOnly || bb.access != Access::ReadOnly {
                        return false;
                    }
                }
            }
        }
    }
    true
}

/// Execution harness: runs launch units functionally, feeds the profiles
/// to the performance model, accumulates app-level metrics.
pub struct Harness {
    pub cfg: DeviceConfig,
    pub opts: ExecOptions,
    models: HashMap<String, PerfModel>,
    pub area: AreaEstimate,
    pub fmax_hz: f64,
    pub metrics: LaunchMetrics,
    pub launches: u64,
    /// Max achieved bandwidth per launch unit (the paper quotes the
    /// dominant kernel's number, not the app max).
    pub bw_by_unit: HashMap<String, f64>,
    /// Max initiation interval across the design (E4a report).
    pub max_ii: u32,
    /// Use the discrete-event simulator instead of the analytic solver.
    pub use_des: bool,
    /// When `Some`, every launch's profiles are recorded here (the trace
    /// tier's acquisition mode — see [`run_built_workload_recorded`]).
    pub trace: Option<ExecTrace>,
    /// The workload's [`Workload::benign_cross_kernel_races`] vouch;
    /// launch units that fail [`unit_depth_invariant`] and carry no vouch
    /// run with exact per-token pipes so their interleaving-sensitive
    /// semantics stay bit-for-bit historical. Defaults to false (the
    /// conservative choice) for directly constructed harnesses.
    pub benign_races: bool,
}

impl Harness {
    pub fn new(app: &App, cfg: &DeviceConfig) -> Harness {
        let union = app.union_program();
        let area = crate::analysis::estimate_program_area(&union, cfg);
        let fmax = cfg.fmax_for_area(area.logic_frac);
        let mut models = HashMap::new();
        let mut max_ii = 1;
        for u in &app.units {
            let mut m = PerfModel::new(u, cfg);
            m.report.fmax_hz = fmax; // whole-design clock
            max_ii = max_ii.max(m.report.max_ii());
            models.insert(u.name.clone(), m);
        }
        Harness {
            cfg: cfg.clone(),
            opts: ExecOptions::default(),
            models,
            area,
            fmax_hz: fmax,
            metrics: LaunchMetrics::zero(fmax),
            launches: 0,
            bw_by_unit: HashMap::new(),
            max_ii,
            use_des: false,
            trace: None,
            benign_races: false,
        }
    }

    /// Run one launch unit: functional execution + performance estimate.
    pub fn launch(&mut self, unit: &Program, img: &MemoryImage) -> Result<(), ExecError> {
        let mut opts = self.opts.clone();
        // chunked transfers widen the producer's run-ahead: only safe
        // when no interleaving can leak into the results
        opts.exact_pipes = !(self.benign_races || unit_depth_invariant(unit));
        let run = run_group(unit, img, &opts)?;
        if let Some(trace) = &mut self.trace {
            let mut profiles = run.profiles.clone();
            for p in &mut profiles {
                // wall clock of the recording host, not part of the trace
                p.host_nanos = 0;
            }
            trace.launches.push(LaunchRecord { unit: unit.name.clone(), profiles });
        }
        self.apply_profiles(unit, &run.profiles);
        Ok(())
    }

    /// The modelling half of [`Harness::launch`]: feed one launch's
    /// profiles to the performance model (or the DES) and accumulate the
    /// app-level metrics. Shared verbatim by the live path and the trace
    /// replay — the byte-identity of replayed measurements depends on
    /// there being exactly one implementation.
    fn apply_profiles(&mut self, unit: &Program, profiles: &[KernelProfile]) {
        let model = &self.models[&unit.name];
        let mut m = model.estimate(profiles);
        if self.use_des {
            let d = crate::sim::des::simulate(unit, model, profiles, &self.cfg, 64);
            m.cycles = d.cycles;
            m.seconds = d.seconds;
            m.bw_bytes_per_s = if d.seconds > 0.0 { m.payload_bytes / d.seconds } else { 0.0 };
        }
        let e = self.bw_by_unit.entry(unit.name.clone()).or_insert(0.0);
        *e = e.max(m.bw_bytes_per_s);
        self.metrics.accumulate(&m);
        self.launches += 1;
    }

    pub fn model(&self, unit: &str) -> &PerfModel {
        &self.models[unit]
    }
}

/// One benchmark of the suite.
pub trait Workload: Sync {
    fn name(&self) -> &'static str;
    /// Table 1 columns.
    fn suite(&self) -> &'static str;
    fn dwarf(&self) -> &'static str;
    fn pattern(&self) -> &'static str;
    fn dataset_desc(&self, scale: Scale) -> String;
    /// The kernel replicated under M2C2.
    fn dominant(&self) -> &'static str;

    /// Baseline single work-item kernels (launch units).
    fn kernels(&self) -> Vec<Kernel>;
    /// Kernels requiring privatization before the split (NW).
    fn privatize_first(&self) -> Vec<&'static str> {
        vec![]
    }

    /// Whether MxCx replication is semantically valid: splitting the outer
    /// iteration range must not break inter-iteration data flow. NW's DP
    /// rows cross replica boundaries, so it opts out (a limitation the
    /// paper's static-partitioning scheme shares).
    fn supports_replication(&self) -> bool {
        true
    }

    /// Programmer guarantee that every cross-kernel shared-buffer race in
    /// this workload's split designs is *benign*: whatever value a racing
    /// read observes, the functional result and the execution profiles
    /// are identical. When true, the trace tier strips pipe depth from
    /// the trace content key even where [`unit_depth_invariant`]'s
    /// conservative syntactic check fails, so a depth sweep shares one
    /// interpreter trace. Defaults to false — NW's races are *not* benign
    /// (its split is only valid below the row width), which is exactly
    /// the case the conservative default protects. Vouched: fw, mis
    /// (PR 4), and the irregular graph trio bfs/color/pagerank (the
    /// ROADMAP vouch audit — each carries its proof at the impl).
    fn benign_cross_kernel_races(&self) -> bool {
        false
    }

    /// Build the app under a variant.
    fn build(&self, variant: Variant) -> Result<App, FeasibilityError> {
        if matches!(variant, Variant::MxCx { .. } | Variant::M1Cx { .. })
            && !self.supports_replication()
        {
            return Err(FeasibilityError::ReplicationUnsupported {
                workload: self.name().to_string(),
            });
        }
        assemble(
            self.name(),
            &self.kernels(),
            self.dominant(),
            &self.privatize_first(),
            variant,
        )
    }

    /// Dataset + scalar args.
    fn image(&self, scale: Scale) -> MemoryImage;

    /// Host driver: launch units against the image until the application
    /// completes (convergence loops, pivot loops, buffer swaps).
    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError>;

    /// Check the image against the native reference implementation.
    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String>;
}

/// Run a workload end to end under a variant; returns the harness with
/// accumulated metrics (validated unless `skip_validate`).
pub fn run_workload(
    w: &dyn Workload,
    variant: Variant,
    scale: Scale,
    cfg: &DeviceConfig,
) -> Result<Harness, String> {
    let app = w.build(variant).map_err(|e| e.to_string())?;
    run_built_workload(w, &app, scale, cfg)
}

/// [`run_workload`] for an already-built app (the coordinator engine
/// builds the app first to derive the measurement's content address).
pub fn run_built_workload(
    w: &dyn Workload,
    app: &App,
    scale: Scale,
    cfg: &DeviceConfig,
) -> Result<Harness, String> {
    run_built_workload_with(w, app, scale, cfg, false)
}

/// [`run_built_workload`] with an explicit estimator choice: `use_des`
/// swaps the analytic performance model for the discrete-event simulator
/// (`pipefwd run --des`). Both estimates cache side by side — the engine's
/// content address includes this flag.
pub fn run_built_workload_with(
    w: &dyn Workload,
    app: &App,
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
) -> Result<Harness, String> {
    run_built_workload_impl(w, app, scale, cfg, use_des, false).map(|(h, _)| h)
}

/// [`run_built_workload_with`] in trace-acquisition mode: the harness
/// records every launch's profiles, and the recorded [`ExecTrace`] comes
/// back beside the harness so the engine can persist it. Error strings
/// (execution failures, `validation:`-prefixed mismatches) are identical
/// to the unrecorded path by construction — both are thin wrappers over
/// [`run_built_workload_impl`].
pub fn run_built_workload_recorded(
    w: &dyn Workload,
    app: &App,
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
) -> Result<(Harness, ExecTrace), String> {
    run_built_workload_impl(w, app, scale, cfg, use_des, true)
        .map(|(h, t)| (h, t.expect("recording was requested")))
}

/// The single execution path behind both wrappers above — the trace
/// tier's replay/cold byte-identity depends on recorded and unrecorded
/// runs sharing every code path but the recording itself.
fn run_built_workload_impl(
    w: &dyn Workload,
    app: &App,
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
    record: bool,
) -> Result<(Harness, Option<ExecTrace>), String> {
    let mut img = w.image(scale);
    let mut h = Harness::new(app, cfg);
    h.use_des = use_des;
    h.benign_races = w.benign_cross_kernel_races();
    if record {
        h.trace = Some(ExecTrace::default());
    }
    w.run(app, &mut img, &mut h).map_err(|e| e.to_string())?;
    w.validate(&img, scale).map_err(|e| format!("{VALIDATION_PREFIX}{e}"))?;
    let trace = h.trace.take();
    Ok((h, trace))
}

/// The trace tier's second stage: rebuild a [`Harness`] for `app` and
/// feed a previously recorded [`ExecTrace`] through the performance
/// model (or the DES when `use_des`) without running the interpreter.
/// The app carries the *actual* pipe depths, so the model and DES see the
/// probed configuration even when the trace was recorded at another
/// depth. Shape mismatches (a stale or corrupt trace against a changed
/// program) are a clean `Err` — the caller re-acquires.
pub fn replay_built_workload(
    app: &App,
    cfg: &DeviceConfig,
    use_des: bool,
    trace: &ExecTrace,
) -> Result<Harness, String> {
    let mut h = Harness::new(app, cfg);
    h.use_des = use_des;
    for (ix, rec) in trace.launches.iter().enumerate() {
        let Some(unit) = app.units.iter().find(|u| u.name == rec.unit) else {
            return Err(format!("trace launch {ix}: no unit `{}` in app {}", rec.unit, app.name));
        };
        if rec.profiles.len() != unit.kernels.len() {
            return Err(format!(
                "trace launch {ix}: {} profiles for {} kernels in unit `{}`",
                rec.profiles.len(),
                unit.kernels.len(),
                rec.unit
            ));
        }
        // every site the model will index must exist in the profile
        let report = &h.models[&unit.name].report;
        for (kr, prof) in report.kernels.iter().zip(&rec.profiles) {
            if kr.sites.iter().any(|s| s.site >= prof.sites.len()) {
                return Err(format!(
                    "trace launch {ix}: profile of `{}` is missing memory sites",
                    kr.name
                ));
            }
        }
        h.apply_profiles(unit, &rec.profiles);
    }
    Ok(h)
}

/// Overlap-mode replay: the launch *graph* is the scheduling unit.
///
/// Replays `trace` through the normal per-launch path first (validating
/// it and accumulating area / fmax / II / payload exactly as
/// [`replay_built_workload`] does), then re-models the app-level time by
/// legalizing the launch chain into persistent stages
/// ([`crate::transform::task_sequence`], which builds the launch
/// dependence DAG with `benign` as the workload's vouch-driven WAR/WAW
/// edge-removal rule) and co-scheduling the stages through
/// [`crate::sim::des::simulate_graph`]. The harness's aggregate
/// `cycles`/`seconds`/`bw_bytes_per_s` are replaced by the overlapped
/// schedule; per-unit bandwidths and every other field keep their
/// sequential per-launch meaning. Overlap always models through the
/// graph DES — a fully chained DAG (e.g. NW) reproduces the sequential
/// DES total exactly, wavefront by wavefront.
///
/// Returns the harness plus the DAG's wavefront count (the E9 report
/// column).
pub fn replay_built_workload_overlapped(
    app: &App,
    cfg: &DeviceConfig,
    benign: bool,
    trace: &ExecTrace,
) -> Result<(Harness, usize), String> {
    let mut h = replay_built_workload(app, cfg, false, trace)?;
    let sched = crate::transform::task_sequence(app, trace, benign)?;
    let g = {
        let launches: Vec<crate::sim::des::GraphLaunch> = trace
            .launches
            .iter()
            .map(|rec| {
                let unit = app.unit(&rec.unit);
                crate::sim::des::GraphLaunch {
                    unit,
                    model: h.model(&unit.name),
                    profiles: &rec.profiles,
                }
            })
            .collect();
        crate::sim::des::simulate_graph(&launches, &sched.stage_of, cfg, 64)
    };
    h.metrics.cycles = g.cycles;
    h.metrics.seconds = g.seconds;
    h.metrics.bw_bytes_per_s =
        if g.seconds > 0.0 { h.metrics.payload_bytes / g.seconds } else { 0.0 };
    Ok((h, sched.stages.len()))
}

/// The registered benchmark suite (Table 1 order).
pub fn suite() -> Vec<Box<dyn Workload>> {
    vec![
        Box::new(bfs::Bfs),
        Box::new(hotspot::Hotspot),
        Box::new(knn::Knn),
        Box::new(hotspot3d::Hotspot3d),
        Box::new(nw::Nw),
        Box::new(backprop::BackProp),
        Box::new(fw::Fw),
        Box::new(mis::Mis),
        Box::new(color::Color),
        Box::new(pagerank::PageRank),
    ]
}

/// Look up one workload by name.
pub fn by_name(name: &str) -> Option<Box<dyn Workload>> {
    suite().into_iter().find(|w| w.name() == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;

    /// The typed error form and the stored string form are the same bytes
    /// in both directions — the store keeps v4's prefixed strings, so no
    /// schema bump rides along with the `MeasureError` promotion.
    #[test]
    fn measure_error_roundtrips_store_strings() {
        for (s, class, msg) in [
            ("validation: nw: m[9] = 1, want 2", ErrorClass::Validation, "nw: m[9] = 1, want 2"),
            ("infeasible: replication unsupported", ErrorClass::Infeasible, "replication unsupported"),
            ("pipe overflow in fw_mem", ErrorClass::Other, "pipe overflow in fw_mem"),
        ] {
            let e = MeasureError::parse(s);
            assert_eq!(e.class, class);
            assert_eq!(e.msg, msg);
            assert_eq!(e.render(), s, "store bytes must be unchanged");
            assert_eq!(MeasureError::from_json(&e.to_json()), Some(e));
        }
        assert!(is_validation_error("validation: x"));
        assert!(!is_validation_error("infeasible: x"));
        assert!(is_infeasible_error("infeasible: x"));
        assert!(!is_infeasible_error("plain defect"));
    }

    #[test]
    fn error_class_labels_roundtrip() {
        for c in [ErrorClass::Validation, ErrorClass::Infeasible, ErrorClass::Other] {
            assert_eq!(ErrorClass::parse(c.label()), Some(c));
        }
        assert_eq!(ErrorClass::parse("warning"), None);
    }

    #[test]
    fn depth_invariance_analysis_classifies_the_suite() {
        // hotspot's split reads temp/power and writes result — disjoint,
        // so the conservative syntactic check already passes
        let hs = by_name("hotspot").unwrap().build(Variant::FeedForward { depth: 1 }).unwrap();
        assert!(hs.units.iter().all(unit_depth_invariant));
        // NW's split shares the read-write `m`: depth-sensitive, no vouch
        let nw = by_name("nw").unwrap();
        let nw_app = nw.build(Variant::FeedForward { depth: 1 }).unwrap();
        assert!(!nw_app.units.iter().all(unit_depth_invariant));
        assert!(!nw.benign_cross_kernel_races());
        // FW/MIS fail the syntactic check (shared dist / min_array) but
        // vouch for benign races
        let fw = by_name("fw").unwrap();
        let fw_app = fw.build(Variant::FeedForward { depth: 1 }).unwrap();
        assert!(!fw_app.units.iter().all(unit_depth_invariant));
        assert!(fw.benign_cross_kernel_races());
        assert!(by_name("mis").unwrap().benign_cross_kernel_races());
        // BFS likewise: the expand split shares the writable `cost`, so
        // the vouch is load-bearing (disjoint visited/unvisited index
        // sets + idempotent writes — see workloads::bfs)
        let bfs = by_name("bfs").unwrap();
        let bfs_app = bfs.build(Variant::FeedForward { depth: 1 }).unwrap();
        assert!(!bfs_app.units.iter().all(unit_depth_invariant));
        assert!(bfs.benign_cross_kernel_races());
        // color/pagerank: the audit found their splits share no writable
        // buffer (cross-buffer ping-pong), so the syntactic check already
        // passes — the vouch documents the semantic argument
        for name in ["color", "pagerank"] {
            let w = by_name(name).unwrap();
            let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
            assert!(app.units.iter().all(unit_depth_invariant), "{name} split shares a buffer");
            assert!(w.benign_cross_kernel_races());
        }
        // single-kernel baselines are trivially invariant
        let base = nw.build(Variant::Baseline).unwrap();
        assert!(base.units.iter().all(unit_depth_invariant));
    }

    /// The two-tier contract: a recorded trace roundtrips through JSON
    /// and replays to bit-identical harness metrics — including when the
    /// replay targets a *different* pipe depth than the recording (the
    /// depth-sweep fast path).
    #[test]
    fn recorded_trace_replays_to_identical_metrics() {
        let cfg = DeviceConfig::pac_a10();
        let w = by_name("hotspot").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let (h, trace) =
            run_built_workload_recorded(w.as_ref(), &app, Scale::Tiny, &cfg, false).unwrap();
        assert!(!trace.launches.is_empty());
        assert_eq!(h.launches as usize, trace.launches.len());

        let doc = crate::util::json::parse(&trace.to_json().to_pretty()).unwrap();
        let rt = ExecTrace::from_json(&doc).expect("trace JSON roundtrips");
        assert_eq!(rt, trace, "serialization must be lossless");

        let r = replay_built_workload(&app, &cfg, false, &rt).unwrap();
        assert_eq!(r.launches, h.launches);
        assert_eq!(r.metrics.seconds, h.metrics.seconds);
        assert_eq!(r.metrics.cycles, h.metrics.cycles);
        assert_eq!(r.max_ii, h.max_ii);
        assert_eq!(r.bw_by_unit, h.bw_by_unit);

        // replaying the depth-1 trace against the depth-100 build must
        // equal a live depth-100 run (hotspot is depth-invariant)
        let deep = w.build(Variant::FeedForward { depth: 100 }).unwrap();
        let (hd, _) =
            run_built_workload_recorded(w.as_ref(), &deep, Scale::Tiny, &cfg, false).unwrap();
        let rd = replay_built_workload(&deep, &cfg, false, &rt).unwrap();
        assert_eq!(
            rd.metrics.seconds, hd.metrics.seconds,
            "depth-100 replay from the depth-1 trace diverged from a live depth-100 run"
        );
        assert_eq!(rd.metrics.cycles, hd.metrics.cycles);
    }

    /// The overlap replay's contract against the sequential DES replay:
    /// strictly lower where the DAG admits overlap (pagerank's ping-pong
    /// collapses to two wavefronts), exactly equal where it refuses
    /// (NW's single launch is a one-wave graph, bit-identical to the
    /// per-launch DES).
    #[test]
    fn overlapped_replay_beats_sequential_where_dag_allows() {
        let cfg = DeviceConfig::pac_a10();
        let pr = by_name("pagerank").unwrap();
        let app = pr.build(Variant::FeedForward { depth: 1 }).unwrap();
        let (_, trace) =
            run_built_workload_recorded(pr.as_ref(), &app, Scale::Tiny, &cfg, false).unwrap();
        let seq = replay_built_workload(&app, &cfg, true, &trace).unwrap();
        let (ov, waves) = replay_built_workload_overlapped(
            &app,
            &cfg,
            pr.benign_cross_kernel_races(),
            &trace,
        )
        .unwrap();
        assert_eq!(waves, 2, "pagerank ping-pong must collapse to two wavefronts");
        assert!(
            ov.metrics.cycles < seq.metrics.cycles,
            "overlap must model strictly lower time: {} vs {}",
            ov.metrics.cycles,
            seq.metrics.cycles
        );
        assert_eq!(ov.launches, seq.launches);
        assert_eq!(ov.max_ii, seq.max_ii);

        let nw = by_name("nw").unwrap();
        let napp = nw.build(Variant::FeedForward { depth: 1 }).unwrap();
        let (_, ntrace) =
            run_built_workload_recorded(nw.as_ref(), &napp, Scale::Tiny, &cfg, false).unwrap();
        let nseq = replay_built_workload(&napp, &cfg, true, &ntrace).unwrap();
        let (nov, nwaves) = replay_built_workload_overlapped(
            &napp,
            &cfg,
            nw.benign_cross_kernel_races(),
            &ntrace,
        )
        .unwrap();
        assert_eq!(nwaves, ntrace.launches.len(), "nw's graph is a chain");
        assert_eq!(
            nov.metrics.cycles, nseq.metrics.cycles,
            "a chained graph must reproduce the sequential DES exactly"
        );
    }

    /// Stale or corrupt traces are a clean `Err` (the engine re-acquires),
    /// never a model-side panic.
    #[test]
    fn replay_rejects_mismatched_traces() {
        let cfg = DeviceConfig::pac_a10();
        let w = by_name("hotspot").unwrap();
        let app = w.build(Variant::FeedForward { depth: 1 }).unwrap();
        let (_, trace) =
            run_built_workload_recorded(w.as_ref(), &app, Scale::Tiny, &cfg, false).unwrap();

        let mut renamed = trace.clone();
        renamed.launches[0].unit = "no_such_unit".into();
        assert!(replay_built_workload(&app, &cfg, false, &renamed).is_err());

        let mut short = trace.clone();
        short.launches[0].profiles.pop();
        assert!(replay_built_workload(&app, &cfg, false, &short).is_err());

        let mut siteless = trace;
        for p in &mut siteless.launches[0].profiles {
            p.sites.clear();
        }
        assert!(replay_built_workload(&app, &cfg, false, &siteless).is_err());
    }
}
