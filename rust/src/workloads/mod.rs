//! The paper's benchmark suite (Table 1) re-implemented on the kernel IR:
//! Rodinia (BFS is Pannotia's formulation, Hotspot, Hotspot3D, KNN, NW,
//! BackProp) and Pannotia (FW, MIS, Graph Coloring, PageRank), plus the
//! §4.2 auto-generated microbenchmarks.
//!
//! Each workload supplies its baseline single work-item kernels, a dataset
//! generator (`Scale`d down from the paper's sizes — see DESIGN.md
//! substitution table), a host driver (convergence loops, ping-pong buffer
//! swaps — the OpenCL host-code role), and a validator against a native
//! Rust reference implementation.

pub mod backprop;
pub mod bfs;
pub mod color;
pub mod datagen;
pub mod fw;
pub mod hotspot;
pub mod hotspot3d;
pub mod knn;
pub mod micro;
pub mod mis;
pub mod nw;
pub mod pagerank;

use crate::analysis::AreaEstimate;
use crate::ir::{Kernel, Program};
use crate::sim::device::DeviceConfig;
use crate::sim::exec::{run_group, ExecError, ExecOptions};
use crate::sim::mem::MemoryImage;
use crate::sim::perf::{LaunchMetrics, PerfModel};
use crate::transform::{
    feedforward, privatize, replicate, replicate_1p, vectorize, FeasibilityError, Variant,
};
use std::collections::HashMap;

/// Prefix distinguishing *result-validation* failures (the computed
/// output diverged from the native reference — an invalid configuration,
/// like NW past its safe pipe depth) from feasibility and execution
/// errors. Depth searches may skip validation-class failures exactly as a
/// paper author drops an invalid configuration; every other error class
/// is a real defect and must propagate.
pub const VALIDATION_PREFIX: &str = "validation: ";

/// Is this stringified cell error a validation-class failure?
pub fn is_validation_error(e: &str) -> bool {
    e.starts_with(VALIDATION_PREFIX)
}

/// Prefix for *feasibility*-class failures (the variant cannot be built
/// for this workload at all — e.g. replication on NW). Applied by
/// `Engine::measure` where the build error is stringified. Searches over
/// a configuration space may skip these like validation failures; they
/// describe the configuration, not a defect.
pub const INFEASIBLE_PREFIX: &str = "infeasible: ";

/// Is this stringified cell error a feasibility-class failure?
pub fn is_infeasible_error(e: &str) -> bool {
    e.starts_with(INFEASIBLE_PREFIX)
}

/// Dataset scale: `Tiny` matches the AOT artifact shapes (PJRT golden
/// validation), `Small` is the default experiment size, `Paper` approaches
/// the paper's dataset sizes (slow under interpretation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Tiny,
    Small,
    Paper,
}

/// A built application: one FPGA design containing several launch units.
#[derive(Debug, Clone)]
pub struct App {
    pub name: String,
    /// Launch units in host-invocation granularity; each unit's kernels
    /// run concurrently (separate queues + pipes).
    pub units: Vec<Program>,
}

impl App {
    /// The union design (all kernels resident on the fabric at once) —
    /// what area/fmax are charged against.
    pub fn union_program(&self) -> Program {
        let mut kernels = vec![];
        let mut pipes = vec![];
        for u in &self.units {
            kernels.extend(u.kernels.iter().cloned());
            pipes.extend(u.pipes.iter().cloned());
        }
        Program { name: self.name.clone(), kernels, pipes }
    }

    pub fn unit(&self, name: &str) -> &Program {
        self.units
            .iter()
            .find(|p| p.name == name)
            .unwrap_or_else(|| panic!("no unit `{name}` in app {}", self.name))
    }
}

/// Assemble an app from baseline kernels under a design variant.
///
/// * `dominant` — the kernel replicated under MxCx/M1Cx (paper step 12:
///   replicate only the execution-time-dominant kernel).
/// * `privatize_first` — kernels that need the NW-style privatization
///   before the feed-forward split is feasible.
pub fn assemble(
    name: &str,
    kernels: &[Kernel],
    dominant: &str,
    privatize_first: &[&str],
    variant: Variant,
) -> Result<App, FeasibilityError> {
    let mut units = vec![];
    for k in kernels {
        let unit = match variant {
            Variant::Baseline => Program::single(k.clone()),
            Variant::FeedForward { depth }
            | Variant::MxCx { depth, .. }
            | Variant::M1Cx { depth, .. }
            | Variant::Vectorized { depth, .. } => {
                let mut kk = k.clone();
                if privatize_first.contains(&k.name.as_str()) {
                    kk = privatize(&kk).expect("privatization applies");
                }
                if let Variant::Vectorized { width, .. } = variant {
                    if k.name == dominant {
                        kk = vectorize(&kk, width);
                        // keep the launch-unit name stable
                        kk.name = k.name.clone();
                    }
                }
                let ff = feedforward(&kk, depth_of(variant).unwrap_or(depth))?;
                match variant {
                    Variant::MxCx { parts, .. } if k.name == dominant => replicate(&ff, parts),
                    Variant::M1Cx { consumers, .. } if k.name == dominant => {
                        replicate_1p(&ff, consumers)
                    }
                    _ => ff,
                }
            }
        };
        let mut unit = unit;
        unit.name = k.name.clone(); // launch units keyed by base kernel name
        units.push(unit);
    }
    Ok(App { name: format!("{name}_{}", variant.label()), units })
}

fn depth_of(v: Variant) -> Option<usize> {
    match v {
        Variant::Baseline => None,
        Variant::FeedForward { depth }
        | Variant::MxCx { depth, .. }
        | Variant::M1Cx { depth, .. }
        | Variant::Vectorized { depth, .. } => Some(depth),
    }
}

/// Execution harness: runs launch units functionally, feeds the profiles
/// to the performance model, accumulates app-level metrics.
pub struct Harness {
    pub cfg: DeviceConfig,
    pub opts: ExecOptions,
    models: HashMap<String, PerfModel>,
    pub area: AreaEstimate,
    pub fmax_hz: f64,
    pub metrics: LaunchMetrics,
    pub launches: u64,
    /// Max achieved bandwidth per launch unit (the paper quotes the
    /// dominant kernel's number, not the app max).
    pub bw_by_unit: HashMap<String, f64>,
    /// Max initiation interval across the design (E4a report).
    pub max_ii: u32,
    /// Use the discrete-event simulator instead of the analytic solver.
    pub use_des: bool,
}

impl Harness {
    pub fn new(app: &App, cfg: &DeviceConfig) -> Harness {
        let union = app.union_program();
        let area = crate::analysis::estimate_program_area(&union, cfg);
        let fmax = cfg.fmax_for_area(area.logic_frac);
        let mut models = HashMap::new();
        let mut max_ii = 1;
        for u in &app.units {
            let mut m = PerfModel::new(u, cfg);
            m.report.fmax_hz = fmax; // whole-design clock
            max_ii = max_ii.max(m.report.max_ii());
            models.insert(u.name.clone(), m);
        }
        Harness {
            cfg: cfg.clone(),
            opts: ExecOptions::default(),
            models,
            area,
            fmax_hz: fmax,
            metrics: LaunchMetrics::zero(fmax),
            launches: 0,
            bw_by_unit: HashMap::new(),
            max_ii,
            use_des: false,
        }
    }

    /// Run one launch unit: functional execution + performance estimate.
    pub fn launch(&mut self, unit: &Program, img: &MemoryImage) -> Result<(), ExecError> {
        let run = run_group(unit, img, &self.opts)?;
        let model = &self.models[&unit.name];
        let mut m = model.estimate(&run.profiles);
        if self.use_des {
            let d = crate::sim::des::simulate(unit, model, &run.profiles, &self.cfg, 64);
            m.cycles = d.cycles;
            m.seconds = d.seconds;
            m.bw_bytes_per_s = if d.seconds > 0.0 { m.payload_bytes / d.seconds } else { 0.0 };
        }
        let e = self.bw_by_unit.entry(unit.name.clone()).or_insert(0.0);
        *e = e.max(m.bw_bytes_per_s);
        self.metrics.accumulate(&m);
        self.launches += 1;
        Ok(())
    }

    pub fn model(&self, unit: &str) -> &PerfModel {
        &self.models[unit]
    }
}

/// One benchmark of the suite.
pub trait Workload: Sync {
    fn name(&self) -> &'static str;
    /// Table 1 columns.
    fn suite(&self) -> &'static str;
    fn dwarf(&self) -> &'static str;
    fn pattern(&self) -> &'static str;
    fn dataset_desc(&self, scale: Scale) -> String;
    /// The kernel replicated under M2C2.
    fn dominant(&self) -> &'static str;

    /// Baseline single work-item kernels (launch units).
    fn kernels(&self) -> Vec<Kernel>;
    /// Kernels requiring privatization before the split (NW).
    fn privatize_first(&self) -> Vec<&'static str> {
        vec![]
    }

    /// Whether MxCx replication is semantically valid: splitting the outer
    /// iteration range must not break inter-iteration data flow. NW's DP
    /// rows cross replica boundaries, so it opts out (a limitation the
    /// paper's static-partitioning scheme shares).
    fn supports_replication(&self) -> bool {
        true
    }

    /// Build the app under a variant.
    fn build(&self, variant: Variant) -> Result<App, FeasibilityError> {
        if matches!(variant, Variant::MxCx { .. } | Variant::M1Cx { .. })
            && !self.supports_replication()
        {
            return Err(FeasibilityError::ReplicationUnsupported {
                workload: self.name().to_string(),
            });
        }
        assemble(
            self.name(),
            &self.kernels(),
            self.dominant(),
            &self.privatize_first(),
            variant,
        )
    }

    /// Dataset + scalar args.
    fn image(&self, scale: Scale) -> MemoryImage;

    /// Host driver: launch units against the image until the application
    /// completes (convergence loops, pivot loops, buffer swaps).
    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError>;

    /// Check the image against the native reference implementation.
    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String>;
}

/// Run a workload end to end under a variant; returns the harness with
/// accumulated metrics (validated unless `skip_validate`).
pub fn run_workload(
    w: &dyn Workload,
    variant: Variant,
    scale: Scale,
    cfg: &DeviceConfig,
) -> Result<Harness, String> {
    let app = w.build(variant).map_err(|e| e.to_string())?;
    run_built_workload(w, &app, scale, cfg)
}

/// [`run_workload`] for an already-built app (the coordinator engine
/// builds the app first to derive the measurement's content address).
pub fn run_built_workload(
    w: &dyn Workload,
    app: &App,
    scale: Scale,
    cfg: &DeviceConfig,
) -> Result<Harness, String> {
    run_built_workload_with(w, app, scale, cfg, false)
}

/// [`run_built_workload`] with an explicit estimator choice: `use_des`
/// swaps the analytic performance model for the discrete-event simulator
/// (`pipefwd run --des`). Both estimates cache side by side — the engine's
/// content address includes this flag.
pub fn run_built_workload_with(
    w: &dyn Workload,
    app: &App,
    scale: Scale,
    cfg: &DeviceConfig,
    use_des: bool,
) -> Result<Harness, String> {
    let mut img = w.image(scale);
    let mut h = Harness::new(app, cfg);
    h.use_des = use_des;
    w.run(app, &mut img, &mut h).map_err(|e| e.to_string())?;
    w.validate(&img, scale).map_err(|e| format!("{VALIDATION_PREFIX}{e}"))?;
    Ok(h)
}

/// The registered benchmark suite (Table 1 order).
pub fn suite() -> Vec<Box<dyn Workload>> {
    vec![
        Box::new(bfs::Bfs),
        Box::new(hotspot::Hotspot),
        Box::new(knn::Knn),
        Box::new(hotspot3d::Hotspot3d),
        Box::new(nw::Nw),
        Box::new(backprop::BackProp),
        Box::new(fw::Fw),
        Box::new(mis::Mis),
        Box::new(color::Color),
        Box::new(pagerank::PageRank),
    ]
}

/// Look up one workload by name.
pub fn by_name(name: &str) -> Option<Box<dyn Workload>> {
    suite().into_iter().find(|w| w.name() == name)
}
