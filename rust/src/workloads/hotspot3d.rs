//! Hotspot3D (Rodinia, Table 2: 0.88x) — 7-point 3D thermal stencil.
//! Same story as 2D Hotspot: cross-buffer accesses, II=1 baseline,
//! feed-forward adds channel overhead.

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen;

pub struct Hotspot3d;

pub const SEED: u64 = 0x3D07;
pub const SDC: f32 = 0.06;
pub const CC: f32 = 0.4;
pub const CXYZ: f32 = 0.1;
pub const AMB: f32 = 80.0;

pub fn dims(scale: Scale) -> (usize, usize, usize, usize) {
    // (nx, ny, nz, steps)
    match scale {
        Scale::Tiny => (16, 16, 4, 1),
        Scale::Small => (64, 64, 8, 3),
        Scale::Paper => (512, 512, 8, 8),
    }
}

/// Edge-replicated reference step.
pub fn reference_step(temp: &[f32], power: &[f32], nx: usize, ny: usize, nz: usize) -> Vec<f32> {
    let mut out = temp.to_vec();
    let at = |x: i64, y: i64, z: i64| -> f32 {
        let x = x.clamp(0, nx as i64 - 1) as usize;
        let y = y.clamp(0, ny as i64 - 1) as usize;
        let z = z.clamp(0, nz as i64 - 1) as usize;
        temp[(z * ny + y) * nx + x]
    };
    for z in 0..nz as i64 {
        for y in 0..ny as i64 {
            for x in 0..nx as i64 {
                let t = at(x, y, z);
                let sum = at(x - 1, y, z)
                    + at(x + 1, y, z)
                    + at(x, y - 1, z)
                    + at(x, y + 1, z)
                    + at(x, y, z - 1)
                    + at(x, y, z + 1);
                let idx = ((z * ny as i64 + y) * nx as i64 + x) as usize;
                out[idx] = t + SDC * (power[idx] + (sum - 6.0 * t) * CXYZ + (AMB - t) * CC);
            }
        }
    }
    out
}

fn patch_boundary(img: &MemoryImage, nx: usize, ny: usize, nz: usize) {
    let temp = img.buf("temp").unwrap();
    let power = img.buf("power").unwrap();
    let result = img.buf("result").unwrap();
    let at = |x: i64, y: i64, z: i64| -> f32 {
        let x = x.clamp(0, nx as i64 - 1) as usize;
        let y = y.clamp(0, ny as i64 - 1) as usize;
        let z = z.clamp(0, nz as i64 - 1) as usize;
        temp.get((z * ny + y) * nx + x).as_f()
    };
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let interior = x > 0 && x < nx - 1 && y > 0 && y < ny - 1 && z > 0 && z < nz - 1;
                if interior {
                    continue;
                }
                let (xi, yi, zi) = (x as i64, y as i64, z as i64);
                let t = at(xi, yi, zi);
                let sum = at(xi - 1, yi, zi)
                    + at(xi + 1, yi, zi)
                    + at(xi, yi - 1, zi)
                    + at(xi, yi + 1, zi)
                    + at(xi, yi, zi - 1)
                    + at(xi, yi, zi + 1);
                let idx = (z * ny + y) * nx + x;
                let v = t + SDC * (power.get(idx).as_f() + (sum - 6.0 * t) * CXYZ + (AMB - t) * CC);
                result.set(idx, crate::ir::Val::F(v));
            }
        }
    }
}

impl Workload for Hotspot3d {
    fn name(&self) -> &'static str {
        "hotspot3d"
    }

    fn suite(&self) -> &'static str {
        "Rodinia"
    }

    fn dwarf(&self) -> &'static str {
        "Structured Grid"
    }

    fn pattern(&self) -> &'static str {
        "Regular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        let (nx, ny, nz, s) = dims(scale);
        format!("{nx}x{ny}x{nz} grid, {s} steps")
    }

    fn dominant(&self) -> &'static str {
        "hotspot3d_kernel"
    }

    fn kernels(&self) -> Vec<Kernel> {
        let idx = || (v("z") * p("ny") + v("y")) * p("nx") + v("x");
        let plane = || p("nx") * p("ny");
        let body = vec![for_(
            "z",
            i(1),
            p("nz") - i(1),
            vec![for_(
                "y",
                i(1),
                p("ny") - i(1),
                vec![for_(
                    "x",
                    i(1),
                    p("nx") - i(1),
                    vec![
                        let_f("t", ld("temp", idx())),
                        let_f(
                            "sum",
                            ld("temp", idx() - i(1))
                                + ld("temp", idx() + i(1))
                                + ld("temp", idx() - p("nx"))
                                + ld("temp", idx() + p("nx"))
                                + ld("temp", idx() - plane())
                                + ld("temp", idx() + plane()),
                        ),
                        store(
                            "result",
                            idx(),
                            v("t")
                                + p("sdc")
                                    * (ld("power", idx())
                                        + (v("sum") - f(6.0) * v("t")) * p("cxyz")
                                        + (p("amb") - v("t")) * p("cc")),
                        ),
                    ],
                )],
            )],
        )];
        vec![KernelBuilder::new("hotspot3d_kernel", KernelKind::SingleWorkItem)
            .buf_ro("temp", Ty::F32)
            .buf_ro("power", Ty::F32)
            .buf_wo("result", Ty::F32)
            .scalar("nx", Ty::I32)
            .scalar("ny", Ty::I32)
            .scalar("nz", Ty::I32)
            .scalar_f("sdc", Ty::F32)
            .scalar_f("cxyz", Ty::F32)
            .scalar_f("cc", Ty::F32)
            .scalar_f("amb", Ty::F32)
            .body(body)
            .finish()]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let (nx, ny, nz, _) = dims(scale);
        let (temp, power) = datagen::hotspot_grids(nz * ny, nx, SEED);
        let mut m = MemoryImage::new();
        m.add_f32s("temp", &temp)
            .add_f32s("power", &power)
            .add_zeros("result", Ty::F32, nx * ny * nz);
        m.set_i("nx", nx as i64)
            .set_i("ny", ny as i64)
            .set_i("nz", nz as i64)
            .set_f("sdc", SDC)
            .set_f("cxyz", CXYZ)
            .set_f("cc", CC)
            .set_f("amb", AMB);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        let nx = img.scalar("nx").unwrap().as_i() as usize;
        let ny = img.scalar("ny").unwrap().as_i() as usize;
        let nz = img.scalar("nz").unwrap().as_i() as usize;
        let steps = [Scale::Tiny, Scale::Small, Scale::Paper]
            .iter()
            .map(|s| dims(*s))
            .find(|d| d.0 == nx && d.2 == nz)
            .map(|d| d.3)
            .unwrap_or(1);
        for _ in 0..steps {
            h.launch(app.unit("hotspot3d_kernel"), img)?;
            patch_boundary(img, nx, ny, nz);
            img.swap_bufs("temp", "result");
        }
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let (nx, ny, nz, steps) = dims(scale);
        let (mut temp, power) = datagen::hotspot_grids(nz * ny, nx, SEED);
        for _ in 0..steps {
            temp = reference_step(&temp, &power, nx, ny, nz);
        }
        let got = img.buf("temp").unwrap().to_f32s();
        for (ix, (g, w)) in got.iter().zip(&temp).enumerate() {
            if (g - w).abs() > 1e-3 {
                return Err(format!("hotspot3d: temp[{ix}] = {g}, want {w}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn baseline_pipelines_at_ii_1() {
        let k = &Hotspot3d.kernels()[0];
        let rep = crate::analysis::report::KernelReport::for_kernel(k);
        assert_eq!(rep.max_ii(), 1);
    }

    #[test]
    fn tiny_variants_validate() {
        let cfg = DeviceConfig::pac_a10();
        run_workload(&Hotspot3d, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let base = run_workload(&Hotspot3d, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff =
            run_workload(&Hotspot3d, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 0.6 && speedup < 1.1, "hotspot3d ff speedup = {speedup}");
    }
}
