//! Breadth-First Search (Pannotia-style frontier BFS, Table 2: 13.84x).
//!
//! Three launch units per level:
//!  * `bfs_clear`  — zero the `updating` mask (stores only, II=1);
//!  * `bfs_kernel` — expand the frontier: for every frontier node walk its
//!    edges and relax unvisited neighbours. `cost` is loaded *and* stored
//!    inside the edge loop, so the conservative compiler serializes the
//!    edge loop (false MLCD — the distance is through different elements);
//!  * `bfs_update` — rebuild `frontier`/`visited` from `updating` and set
//!    the stop flag (all cross-buffer, II=1).
//!
//! The host iterates levels until the stop flag stays clear.

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty, Val};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen::{self, CsrGraph};

pub struct Bfs;

pub const SEED: u64 = 0xBF5;
pub const INF: i64 = 1 << 30;

pub fn graph(scale: Scale) -> CsrGraph {
    match scale {
        Scale::Tiny => datagen::random_graph(512, 8, SEED),
        Scale::Small => datagen::random_graph(40_000, 12, SEED),
        Scale::Paper => datagen::random_graph(2_000_000, 12, SEED),
    }
}

/// Native reference: BFS levels from node 0.
pub fn reference(g: &CsrGraph) -> Vec<i64> {
    let mut cost = vec![INF; g.n];
    cost[0] = 0;
    let mut frontier = vec![0usize];
    let mut level = 0i64;
    while !frontier.is_empty() {
        let mut next = vec![];
        for &v in &frontier {
            for &u in g.neighbors(v) {
                if cost[u as usize] == INF {
                    cost[u as usize] = level + 1;
                    next.push(u as usize);
                }
            }
        }
        frontier = next;
        level += 1;
    }
    cost
}

impl Workload for Bfs {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn suite(&self) -> &'static str {
        "Rodinia"
    }

    fn dwarf(&self) -> &'static str {
        "Graph Traversal"
    }

    fn pattern(&self) -> &'static str {
        "Irregular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        let g = match scale {
            Scale::Tiny => "512".to_string(),
            Scale::Small => "40k".to_string(),
            Scale::Paper => "2M".to_string(),
        };
        format!("uniform random graph, #nodes={g}, avg degree 12")
    }

    fn dominant(&self) -> &'static str {
        "bfs_kernel"
    }

    /// The expand kernel's split shares `cost`: the memory kernel
    /// re-reads `cost[t2]` for frontier nodes while the compute kernel
    /// writes `cost[id]` for their unvisited neighbours. Every such race
    /// is benign:
    ///
    /// * the racing index sets are **disjoint** — reads are guarded by
    ///   `frontier[t2] == 1` and frontier ⊆ visited (`bfs_update` sets
    ///   both together, and node 0 starts with both), while writes are
    ///   guarded by `visited[id] == 0`; `visited` itself is only written
    ///   by the separate `bfs_update` launch, so the guard is constant
    ///   for the whole launch;
    /// * concurrent writes to one `cost[id]` all store the identical
    ///   value `level + 1` (every frontier node of one level carries
    ///   `cost == level`), and `updating[id] = 1` is a **monotonic OR**
    ///   idempotent under any arrival order.
    ///
    /// No interleaving — and hence no pipe depth, chunking, or replica
    /// schedule (MxCx partitions `t2` disjointly, so the same guards
    /// apply across replicas) — can change a value read, the control
    /// flow it drives, or the recorded address streams, so the execution
    /// trace is depth-invariant and a depth ladder runs the interpreter
    /// once. This vouch is load-bearing: the conservative syntactic check
    /// (`unit_depth_invariant`) rejects the split over the shared
    /// writable `cost`.
    fn benign_cross_kernel_races(&self) -> bool {
        true
    }

    fn kernels(&self) -> Vec<Kernel> {
        let clear = KernelBuilder::new("bfs_clear", KernelKind::SingleWorkItem)
            .buf_wo("updating", Ty::I32)
            .scalar("num_nodes", Ty::I32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![store("updating", v("t2"), i(0))],
            )])
            .finish();

        let expand = KernelBuilder::new("bfs_kernel", KernelKind::SingleWorkItem)
            .buf_ro("frontier", Ty::I32)
            .buf_ro("row", Ty::I32)
            .buf_ro("col", Ty::I32)
            .buf_ro("visited", Ty::I32)
            .buf_rw("cost", Ty::I32)
            .buf_wo("updating", Ty::I32)
            .scalar("num_nodes", Ty::I32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![if_(
                    ld("frontier", v("t2")).eq_(i(1)),
                    vec![
                        let_i("start", ld("row", v("t2"))),
                        let_i("end", ld("row", v("t2") + i(1))),
                        for_(
                            "e",
                            v("start"),
                            v("end"),
                            vec![
                                let_i("id", ld("col", v("e"))),
                                if_(
                                    ld("visited", v("id")).eq_(i(0)),
                                    vec![
                                        // cost loaded AND stored here: the
                                        // false MLCD that serializes the loop
                                        let_i("c", ld("cost", v("t2"))),
                                        store("cost", v("id"), v("c") + i(1)),
                                        store("updating", v("id"), i(1)),
                                    ],
                                ),
                            ],
                        ),
                    ],
                )],
            )])
            .finish();

        let update = KernelBuilder::new("bfs_update", KernelKind::SingleWorkItem)
            .buf_ro("updating", Ty::I32)
            .buf_wo("frontier", Ty::I32)
            .buf_wo("visited", Ty::I32)
            .buf_wo("stop", Ty::I32)
            .scalar("num_nodes", Ty::I32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![
                    let_i("u", ld("updating", v("t2"))),
                    store("frontier", v("t2"), v("u")),
                    if_(
                        v("u").eq_(i(1)),
                        vec![store("visited", v("t2"), i(1)), store("stop", i(0), i(1))],
                    ),
                ],
            )])
            .finish();

        vec![clear, expand, update]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let g = graph(scale);
        let mut m = MemoryImage::new();
        let mut cost = vec![INF; g.n];
        cost[0] = 0;
        let mut frontier = vec![0i64; g.n];
        frontier[0] = 1;
        let mut visited = vec![0i64; g.n];
        visited[0] = 1;
        m.add_i64s("row", &g.row)
            .add_i64s("col", &g.col)
            .add_i64s("cost", &cost)
            .add_i64s("frontier", &frontier)
            .add_i64s("visited", &visited)
            .add_zeros("updating", Ty::I32, g.n)
            .add_zeros("stop", Ty::I32, 1);
        m.set_i("num_nodes", g.n as i64);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        let n = img.scalar("num_nodes").unwrap().as_i();
        for _level in 0..n {
            img.buf("stop").unwrap().set(0, Val::I(0));
            h.launch(app.unit("bfs_clear"), img)?;
            h.launch(app.unit("bfs_kernel"), img)?;
            h.launch(app.unit("bfs_update"), img)?;
            if img.buf("stop").unwrap().get(0).as_i() == 0 {
                break;
            }
        }
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let g = graph(scale);
        let want = reference(&g);
        let got = img.buf("cost").unwrap().to_i64s();
        for (ix, (g_, w)) in got.iter().zip(&want).enumerate() {
            if g_ != w {
                return Err(format!("bfs: cost[{ix}] = {g_}, want {w}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn expand_kernel_is_serialized_on_cost() {
        let ks = Bfs.kernels();
        let rep = crate::analysis::report::KernelReport::for_kernel(&ks[1]);
        assert!(rep.max_ii() > 200, "ii = {}", rep.max_ii());
        let ser = rep.loops.iter().find(|l| l.serialized_by.is_some()).unwrap();
        assert_eq!(ser.serialized_by.as_deref(), Some("cost"));
        assert_eq!(ser.depth, 1); // the edge loop, not the node loop
        // clear/update pipeline fine
        for k in [&ks[0], &ks[2]] {
            assert_eq!(crate::analysis::report::KernelReport::for_kernel(k).max_ii(), 1);
        }
    }

    #[test]
    fn tiny_baseline_validates() {
        let cfg = DeviceConfig::pac_a10();
        run_workload(&Bfs, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
    }

    #[test]
    fn tiny_ff_validates_and_speeds_up() {
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&Bfs, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff = run_workload(&Bfs, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 2.0, "bfs tiny ff speedup = {speedup}");
    }
}
