//! Maximal Independent Set (Pannotia, Table 2: 6.47x; §3 in-text: removing
//! the false MLCDs lifts max bandwidth from 208 MB/s to 2116 MB/s).
//!
//! Luby-style rounds. The gather kernel (`mis_kernel`, the paper's Fig. 2)
//! computes per active node the min value over active neighbours and
//! whether any neighbour is already selected; it *accumulates* into
//! `min_array` (load+store of the same element), which the conservative
//! compiler serializes — the false MLCD behind the paper's 208 MB/s
//! baseline. The decision kernel and the reset kernel are cross-buffer and
//! pipeline fine.

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty, Val};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen::{self, CsrGraph};

pub struct Mis;

pub const SEED: u64 = 0x3115;
pub const BIG: f32 = 1.0e30;

pub fn graph(scale: Scale) -> CsrGraph {
    match scale {
        Scale::Tiny => datagen::circuit_graph(128, 8, SEED), // artifact size
        Scale::Small => datagen::circuit_graph(30_000, 12, SEED),
        Scale::Paper => datagen::circuit_graph(1_500_000, 12, SEED),
    }
}

/// Native reference: same synchronous rounds.
/// c: -1 active, >=0 selected at that round, -2 removed.
pub fn reference(g: &CsrGraph, values: &[f32]) -> Vec<i64> {
    let mut c = vec![-1i64; g.n];
    for round in 0..g.n as i64 {
        let mut changed = false;
        let mut decide = vec![];
        for v in 0..g.n {
            if c[v] != -1 {
                continue;
            }
            changed = true;
            let mut mn = BIG;
            let mut nbr_sel = false;
            for &u in g.neighbors(v) {
                match c[u as usize] {
                    -1 => mn = mn.min(values[u as usize]),
                    x if x >= 0 => nbr_sel = true,
                    _ => {}
                }
            }
            if nbr_sel {
                decide.push((v, -2));
            } else if values[v] <= mn {
                decide.push((v, round));
            }
        }
        if !changed {
            break;
        }
        for (v, val) in decide {
            c[v] = val;
        }
    }
    c
}

impl Workload for Mis {
    fn name(&self) -> &'static str {
        "mis"
    }

    fn suite(&self) -> &'static str {
        "Pannotia"
    }

    fn dwarf(&self) -> &'static str {
        "Graph Traversal"
    }

    fn pattern(&self) -> &'static str {
        "Irregular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        format!(
            "circuit-like graph (G3_circuit stand-in), #nodes={}",
            graph(scale).n
        )
    }

    fn dominant(&self) -> &'static str {
        "mis_kernel"
    }

    /// The gather kernel's split shares `min_array` (memory kernel loads
    /// `min_array[t2]` for the accumulate, compute kernel stores it), but
    /// the race is benign: the compute kernel writes index `t2` only
    /// after receiving that iteration's tokens, i.e. strictly after the
    /// memory kernel issued the load of the same index, and each index is
    /// written at most once per launch — no interleaving (and hence no
    /// pipe depth) can change the values read. Replicas partition `t2`
    /// disjointly, so the argument carries over to MxCx. The trace tier
    /// therefore shares one interpreter trace across the depth sweep.
    fn benign_cross_kernel_races(&self) -> bool {
        true
    }

    fn kernels(&self) -> Vec<Kernel> {
        let reset = KernelBuilder::new("mis_reset", KernelKind::SingleWorkItem)
            .buf_wo("min_array", Ty::F32)
            .buf_wo("nbr_sel", Ty::I32)
            .buf_wo("stop", Ty::I32)
            .scalar("num_nodes", Ty::I32)
            .body(vec![
                store("stop", i(0), i(0)),
                for_(
                    "t2",
                    i(0),
                    p("num_nodes"),
                    vec![
                        store("min_array", v("t2"), f(BIG)),
                        store("nbr_sel", v("t2"), i(0)),
                    ],
                ),
            ])
            .finish();

        // Fig. 2-shaped gather with the accumulating min_array store.
        let gather = KernelBuilder::new("mis_kernel", KernelKind::SingleWorkItem)
            .buf_ro("c_array", Ty::I32)
            .buf_ro("row", Ty::I32)
            .buf_ro("col", Ty::I32)
            .buf_ro("node_value", Ty::F32)
            .buf_rw("min_array", Ty::F32)
            .buf_wo("nbr_sel", Ty::I32)
            .buf_wo("stop", Ty::I32)
            .scalar("num_nodes", Ty::I32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![if_(
                    ld("c_array", v("t2")).eq_(i(-1)),
                    vec![
                        store("stop", i(0), i(1)),
                        let_i("start", ld("row", v("t2"))),
                        let_i("end", ld("row", v("t2") + i(1))),
                        let_f("mn", f(BIG)),
                        let_i("sel", i(0)),
                        for_(
                            "e",
                            v("start"),
                            v("end"),
                            vec![
                                let_i("j", ld("col", v("e"))),
                                let_i("cj", ld("c_array", v("j"))),
                                if_else(
                                    v("cj").eq_(i(-1)),
                                    vec![assign("mn", v("mn").min(ld("node_value", v("j"))))],
                                    vec![if_(v("cj").ge(i(0)), vec![assign("sel", i(1))])],
                                ),
                            ],
                        ),
                        // accumulate (same-element load+store: the false MLCD)
                        store("min_array", v("t2"), ld("min_array", v("t2")).min(v("mn"))),
                        store("nbr_sel", v("t2"), v("sel")),
                    ],
                )],
            )])
            .finish();

        // Decision kernel: cross-buffer ping-pong, II=1.
        let decide = KernelBuilder::new("mis_decide", KernelKind::SingleWorkItem)
            .buf_ro("c_array", Ty::I32)
            .buf_ro("node_value", Ty::F32)
            .buf_ro("min_array", Ty::F32)
            .buf_ro("nbr_sel", Ty::I32)
            .buf_wo("c_next", Ty::I32)
            .scalar("num_nodes", Ty::I32)
            .scalar("round", Ty::I32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![
                    let_i("c", ld("c_array", v("t2"))),
                    if_else(
                        v("c").eq_(i(-1)),
                        vec![if_else(
                            ld("nbr_sel", v("t2")).eq_(i(1)),
                            vec![store("c_next", v("t2"), i(-2))],
                            vec![if_else(
                                ld("node_value", v("t2")).le(ld("min_array", v("t2"))),
                                vec![store("c_next", v("t2"), p("round"))],
                                vec![store("c_next", v("t2"), i(-1))],
                            )],
                        )],
                        vec![store("c_next", v("t2"), v("c"))],
                    ),
                ],
            )])
            .finish();

        vec![reset, gather, decide]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let g = graph(scale);
        let values = datagen::node_values(g.n, SEED ^ 1);
        let mut m = MemoryImage::new();
        m.add_i64s("row", &g.row)
            .add_i64s("col", &g.col)
            .add_f32s("node_value", &values)
            .add_i64s("c_array", &vec![-1; g.n])
            .add_zeros("c_next", Ty::I32, g.n)
            .add_f32s("min_array", &vec![BIG; g.n])
            .add_zeros("nbr_sel", Ty::I32, g.n)
            .add_zeros("stop", Ty::I32, 1);
        m.set_i("num_nodes", g.n as i64).set_i("round", 0);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        let n = img.scalar("num_nodes").unwrap().as_i();
        for round in 0..n {
            img.set_scalar("round", Val::I(round));
            h.launch(app.unit("mis_reset"), img)?;
            h.launch(app.unit("mis_kernel"), img)?;
            if img.buf("stop").unwrap().get(0).as_i() == 0 {
                break;
            }
            h.launch(app.unit("mis_decide"), img)?;
            img.swap_bufs("c_array", "c_next");
        }
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let g = graph(scale);
        let values = datagen::node_values(g.n, SEED ^ 1);
        let want = reference(&g, &values);
        let got = img.buf("c_array").unwrap().to_i64s();
        if got != want {
            let ix = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!("mis: c[{ix}] = {}, want {}", got[ix], want[ix]));
        }
        // Property checks: independence + maximality.
        for v in 0..g.n {
            if got[v] >= 0 {
                for &u in g.neighbors(v) {
                    if got[u as usize] >= 0 && u as usize != v {
                        return Err(format!("mis: adjacent {v},{u} both selected"));
                    }
                }
            } else {
                let any_sel = g.neighbors(v).iter().any(|&u| got[u as usize] >= 0);
                if !any_sel {
                    return Err(format!("mis: node {v} unselected with no selected neighbour"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn gather_kernel_serialized_on_min_array_outer_loop() {
        let ks = Mis.kernels();
        let rep = crate::analysis::report::KernelReport::for_kernel(&ks[1]);
        let ser = rep.loops.iter().find(|l| l.serialized_by.is_some()).unwrap();
        assert_eq!(ser.serialized_by.as_deref(), Some("min_array"));
        assert_eq!(ser.depth, 0); // node loop: no overlap relief
        assert!(ser.ii > 200);
    }

    #[test]
    fn tiny_baseline_validates() {
        let cfg = DeviceConfig::pac_a10();
        run_workload(&Mis, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
    }

    #[test]
    fn tiny_variants_agree_and_ff_speeds_up() {
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&Mis, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff = run_workload(&Mis, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        run_workload(&Mis, Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 1.5, "mis tiny ff speedup = {speedup}");
    }
}
