//! k-Nearest Neighbours (Rodinia `nn`, Table 1) — fully regular streaming:
//! distance of every reference point to one query, top-k selected by the
//! host (as Rodinia's host code does). The baseline already pipelines; the
//! paper's Table 2 omits it, and our harness confirms FF is ~flat here.
//! Cross-validated against artifacts/knn.hlo.txt at Tiny scale.

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Stmt, Ty};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen;

pub struct Knn;

pub const SEED: u64 = 0x4E4E;
pub const DIMS: usize = 8;

pub fn points(scale: Scale) -> usize {
    match scale {
        Scale::Tiny => 1024, // matches artifacts/knn.hlo.txt
        Scale::Small => 100_000,
        Scale::Paper => 1_000_000,
    }
}

pub fn reference(pts: &[f32], q: &[f32], n: usize) -> Vec<f32> {
    (0..n)
        .map(|i| {
            let mut acc = 0.0f32;
            for d in 0..DIMS {
                let diff = pts[i * DIMS + d] - q[d];
                acc += diff * diff;
            }
            acc
        })
        .collect()
}

impl Workload for Knn {
    fn name(&self) -> &'static str {
        "knn"
    }

    fn suite(&self) -> &'static str {
        "Rodinia"
    }

    fn dwarf(&self) -> &'static str {
        "Dense Linear Algebra"
    }

    fn pattern(&self) -> &'static str {
        "Regular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        format!("{} points x {DIMS} dims, 1 query", points(scale))
    }

    fn dominant(&self) -> &'static str {
        "knn_kernel"
    }

    fn kernels(&self) -> Vec<Kernel> {
        // Unrolled 8-dim distance: acc chains within one iteration only
        // (no loop-carried recurrence), II=1.
        let mut body_inner: Vec<Stmt> = vec![let_f("acc", f(0.0))];
        for d in 0..DIMS as i64 {
            body_inner.push(let_f(
                &format!("d{d}"),
                ld("pts", v("t2") * i(DIMS as i64) + i(d)) - ld("q", i(d)),
            ));
            body_inner.push(assign(
                "acc",
                v("acc") + v(&format!("d{d}")) * v(&format!("d{d}")),
            ));
        }
        body_inner.push(store("dist", v("t2"), v("acc")));
        vec![KernelBuilder::new("knn_kernel", KernelKind::SingleWorkItem)
            .buf_ro("pts", Ty::F32)
            .buf_ro("q", Ty::F32)
            .buf_wo("dist", Ty::F32)
            .scalar("num_points", Ty::I32)
            .body(vec![for_("t2", i(0), p("num_points"), body_inner)])
            .finish()]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let n = points(scale);
        let mut m = MemoryImage::new();
        m.add_f32s("pts", &datagen::matrix(n, DIMS, 1.0, SEED))
            .add_f32s("q", &datagen::matrix(1, DIMS, 1.0, SEED ^ 1))
            .add_zeros("dist", Ty::F32, n);
        m.set_i("num_points", n as i64);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        h.launch(app.unit("knn_kernel"), img)?;
        // host-side top-k (Rodinia does the same selection on the CPU)
        let dist = img.buf("dist").unwrap().to_f32s();
        let mut idx: Vec<usize> = (0..dist.len()).collect();
        idx.sort_by(|&a, &b| dist[a].total_cmp(&dist[b]));
        let _top5: Vec<usize> = idx.into_iter().take(5).collect();
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let n = points(scale);
        let pts = datagen::matrix(n, DIMS, 1.0, SEED);
        let q = datagen::matrix(1, DIMS, 1.0, SEED ^ 1);
        let want = reference(&pts, &q, n);
        let got = img.buf("dist").unwrap().to_f32s();
        for (ix, (g, w)) in got.iter().zip(&want).enumerate() {
            if (g - w).abs() > 1e-4 * (1.0 + w.abs()) {
                return Err(format!("knn: dist[{ix}] = {g}, want {w}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::AccessPattern;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn all_point_loads_strided_regular() {
        let k = &Knn.kernels()[0];
        let rep = crate::analysis::report::KernelReport::for_kernel(k);
        assert_eq!(rep.max_ii(), 1);
        let strided = rep
            .sites
            .iter()
            .filter(|s| s.buf == "pts" && s.pattern == AccessPattern::Strided(DIMS as i64))
            .count();
        assert_eq!(strided, DIMS);
    }

    #[test]
    fn tiny_variants_validate() {
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&Knn, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff = run_workload(&Knn, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 0.5 && speedup < 1.2, "knn ff speedup = {speedup}");
    }
}
