//! PageRank (Pannotia, Table 2: 0.96x — bandwidth-saturated baseline).
//!
//! Pull-style CSR power iteration: a contribution kernel (pr/degree,
//! sequential, II=1) and an irregular gather kernel that accumulates
//! neighbour contributions. Both are cross-buffer (ping-pong), so the
//! baseline pipelines and is DRAM-bound; FF moves the same traffic and
//! changes nothing (the paper's explanation for why M2C2 is also flat:
//! "highly optimized memory operations with high bandwidth utilization").

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen::{self, CsrGraph};

pub struct PageRank;

pub const SEED: u64 = 0x9A6E;
pub const DAMPING: f32 = 0.85;
pub const ROUNDS: usize = 10;

pub fn graph(scale: Scale) -> CsrGraph {
    match scale {
        Scale::Tiny => datagen::random_graph(128, 6, SEED), // artifact size
        Scale::Small => datagen::random_graph(30_000, 8, SEED),
        Scale::Paper => datagen::random_graph(1_000_000, 10, SEED),
    }
}

/// Native reference (same iteration order / f32 arithmetic).
pub fn reference(g: &CsrGraph, rounds: usize) -> Vec<f32> {
    let n = g.n;
    let mut pr = vec![1.0f32 / n as f32; n];
    for _ in 0..rounds {
        let contrib: Vec<f32> = (0..n)
            .map(|v| {
                let d = g.degree(v).max(1) as f32;
                pr[v] / d
            })
            .collect();
        let mut next = vec![0.0f32; n];
        for v in 0..n {
            let mut sum = 0.0f32;
            for &u in g.neighbors(v) {
                sum += contrib[u as usize];
            }
            next[v] = (1.0 - DAMPING) / n as f32 + DAMPING * sum;
        }
        pr = next;
    }
    pr
}

impl Workload for PageRank {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn suite(&self) -> &'static str {
        "Pannotia"
    }

    fn dwarf(&self) -> &'static str {
        "Graph Traversal"
    }

    fn pattern(&self) -> &'static str {
        "Irregular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        format!("uniform random graph, #nodes={}, {ROUNDS} power iterations", graph(scale).n)
    }

    fn dominant(&self) -> &'static str {
        "pagerank_kernel"
    }

    /// Audited benign (ROADMAP vouch audit): the rank accumulator
    /// `pr_next` is a pure sum target — written by the gather launch,
    /// read only *next* iteration after the host's ping-pong swap — and
    /// `contrib` is produced by the preceding `pagerank_contrib` launch
    /// and read-only during the gather. Launches are sequential, so
    /// within any single launch the split pair shares no writable buffer
    /// (the memory kernel owns all loads of `pr`/`row`/`col`/`contrib`,
    /// the compute kernel all stores of `contrib`/`pr_next`, over
    /// disjoint buffers). The syntactic `unit_depth_invariant` check
    /// already accepts every split unit; the vouch records the semantic
    /// argument (accumulate-into-a-buffer-read-next-iteration) so it
    /// survives transform changes and covers MxCx, where replicas write
    /// disjoint `t2` slices of the same sum buffer.
    fn benign_cross_kernel_races(&self) -> bool {
        true
    }

    fn kernels(&self) -> Vec<Kernel> {
        let contrib = KernelBuilder::new("pagerank_contrib", KernelKind::SingleWorkItem)
            .buf_ro("pr", Ty::F32)
            .buf_ro("row", Ty::I32)
            .buf_wo("contrib", Ty::F32)
            .scalar("num_nodes", Ty::I32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![
                    let_i("deg", ld("row", v("t2") + i(1)) - ld("row", v("t2"))),
                    let_i("d", v("deg").max(i(1))),
                    store("contrib", v("t2"), ld("pr", v("t2")) / itof(v("d"))),
                ],
            )])
            .finish();

        let gather = KernelBuilder::new("pagerank_kernel", KernelKind::SingleWorkItem)
            .buf_ro("row", Ty::I32)
            .buf_ro("col", Ty::I32)
            .buf_ro("contrib", Ty::F32)
            .buf_wo("pr_next", Ty::F32)
            .scalar("num_nodes", Ty::I32)
            .scalar_f("base", Ty::F32)
            .scalar_f("damping", Ty::F32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![
                    let_i("start", ld("row", v("t2"))),
                    let_i("end", ld("row", v("t2") + i(1))),
                    let_f("sum", f(0.0)),
                    for_(
                        "e",
                        v("start"),
                        v("end"),
                        vec![assign("sum", v("sum") + ld("contrib", ld("col", v("e"))))],
                    ),
                    store("pr_next", v("t2"), p("base") + p("damping") * v("sum")),
                ],
            )])
            .finish();

        vec![contrib, gather]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let g = graph(scale);
        let mut m = MemoryImage::new();
        m.add_i64s("row", &g.row)
            .add_i64s("col", &g.col)
            .add_f32s("pr", &vec![1.0 / g.n as f32; g.n])
            .add_zeros("contrib", Ty::F32, g.n)
            .add_zeros("pr_next", Ty::F32, g.n);
        m.set_i("num_nodes", g.n as i64)
            .set_f("base", (1.0 - DAMPING) / g.n as f32)
            .set_f("damping", DAMPING);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        for _ in 0..ROUNDS {
            h.launch(app.unit("pagerank_contrib"), img)?;
            h.launch(app.unit("pagerank_kernel"), img)?;
            img.swap_bufs("pr", "pr_next");
        }
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let g = graph(scale);
        let want = reference(&g, ROUNDS);
        let got = img.buf("pr").unwrap().to_f32s();
        let sum: f32 = got.iter().sum();
        if (sum - 1.0).abs() > 0.05 {
            return Err(format!("pagerank: probability mass {sum}"));
        }
        for (ix, (g_, w)) in got.iter().zip(&want).enumerate() {
            if (g_ - w).abs() > 1e-5 + 1e-3 * w.abs() {
                return Err(format!("pagerank: pr[{ix}] = {g_}, want {w}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn gather_has_dlcd_but_no_mlcd() {
        let ks = PageRank.kernels();
        let rep = crate::analysis::report::KernelReport::for_kernel(&ks[1]);
        assert!(rep.loops.iter().all(|l| l.serialized_by.is_none()));
        assert!(rep.loops.iter().any(|l| l.dlcd_var.as_deref() == Some("sum")));
    }

    #[test]
    fn tiny_flat_speedup_and_valid() {
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&PageRank, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff =
            run_workload(&PageRank, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 0.6 && speedup < 1.4, "pagerank ff speedup = {speedup}");
    }
}
