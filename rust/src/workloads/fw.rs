//! Floyd–Warshall (Pannotia) — the paper's headline benchmark: 64.95x from
//! the feed-forward split (Table 2), driven by a false MLCD on `dist` that
//! serializes the relaxation loop at II=285 (E4a).
//!
//! Host loops over pivots; the kernel relaxes all pairs for a fixed pivot.
//! Note the paper's §4.2 observation that FF+pipes makes the concurrent
//! read/write of `dist` benign: for pivot k, row k and column k are fixed
//! points of the relaxation, so the memory and compute kernels never race
//! on a value that changes.

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty, Val};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen;

pub struct Fw;

pub const SEED: u64 = 0xF10D;

pub fn size(scale: Scale) -> usize {
    match scale {
        Scale::Tiny => 64, // matches artifacts/fw.hlo.txt
        Scale::Small => 128,
        Scale::Paper => 512,
    }
}

/// Native reference (same f32 evaluation order as the kernel).
pub fn reference(dist: &mut [f32], n: usize) {
    for k in 0..n {
        for i in 0..n {
            let dik = dist[i * n + k];
            for j in 0..n {
                let cand = dik + dist[k * n + j];
                if cand < dist[i * n + j] {
                    dist[i * n + j] = cand;
                }
            }
        }
    }
}

impl Workload for Fw {
    fn name(&self) -> &'static str {
        "fw"
    }

    fn suite(&self) -> &'static str {
        "Pannotia"
    }

    fn dwarf(&self) -> &'static str {
        "Graph Traversal"
    }

    fn pattern(&self) -> &'static str {
        "Irregular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        format!("dense distance matrix, |V|={}", size(scale))
    }

    fn dominant(&self) -> &'static str {
        "fw_kernel"
    }

    /// The split pair shares `dist` (memory kernel reads it, compute
    /// kernel writes it), but every race is benign: within pass `k` the
    /// compute kernel only lags the memory kernel (it needs the tokens
    /// first), so `dist[ij]` is always read before its own update, and
    /// the cells racing reads *can* observe early — the pivot row and
    /// column — are fixed points of pass `k`'s min-update
    /// (`dist[i][k] = min(dist[i][k], dist[i][k] + dist[k][k])` with
    /// `dist[k][k] = 0`). Any interleaving reads the same values, so the
    /// execution trace is pipe-depth invariant and a depth sweep runs the
    /// interpreter once.
    fn benign_cross_kernel_races(&self) -> bool {
        true
    }

    fn kernels(&self) -> Vec<Kernel> {
        // for (i) for (j) dist[i*n+j] = min(dist[i*n+j], dist[i*n+k] + dist[k*n+j])
        let body = vec![for_(
            "i2",
            i(0),
            p("n"),
            vec![for_(
                "j2",
                i(0),
                p("n"),
                vec![store(
                    "dist",
                    v("i2") * p("n") + v("j2"),
                    ld("dist", v("i2") * p("n") + v("j2"))
                        .min(ld("dist", v("i2") * p("n") + p("k")) + ld("dist", p("k") * p("n") + v("j2"))),
                )],
            )],
        )];
        vec![KernelBuilder::new("fw_kernel", KernelKind::SingleWorkItem)
            .buf_rw("dist", Ty::F32)
            .scalar("n", Ty::I32)
            .scalar("k", Ty::I32)
            .body(body)
            .finish()]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let n = size(scale);
        let mut m = MemoryImage::new();
        m.add_f32s("dist", &datagen::distance_matrix(n, SEED));
        m.set_i("n", n as i64).set_i("k", 0);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        let n = img.scalar("n").unwrap().as_i();
        for k in 0..n {
            img.set_scalar("k", Val::I(k));
            h.launch(app.unit("fw_kernel"), img)?;
        }
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let n = size(scale);
        let mut want = datagen::distance_matrix(n, SEED);
        reference(&mut want, n);
        let got = img.buf("dist").unwrap().to_f32s();
        for (ix, (g, w)) in got.iter().zip(&want).enumerate() {
            if (g - w).abs() > 1e-3 * w.abs().max(1.0) {
                return Err(format!("fw: dist[{ix}] = {g}, want {w}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn baseline_is_serialized_at_285() {
        let k = &Fw.kernels()[0];
        let rep = crate::analysis::report::KernelReport::for_kernel(k);
        assert_eq!(rep.max_ii(), 285);
    }

    #[test]
    fn tiny_baseline_validates() {
        let cfg = DeviceConfig::pac_a10();
        run_workload(&Fw, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
    }

    #[test]
    fn tiny_ff_matches_baseline_and_is_much_faster() {
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&Fw, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff = run_workload(&Fw, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 20.0, "fw tiny ff speedup = {speedup}");
        // FF must pipeline at II=1 (E4a)
        assert_eq!(ff.max_ii, 1);
        assert_eq!(base.max_ii, 285);
    }

    #[test]
    fn m2c2_validates() {
        let cfg = DeviceConfig::pac_a10();
        run_workload(&Fw, Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny, &cfg).unwrap();
    }
}
