//! Graph Coloring (Pannotia `color_max`, Table 2: 1.02x — the benchmark
//! where feed-forward neither helps nor hurts).
//!
//! Unlike MIS, the gather kernel writes only cross-buffer outputs
//! (`node_max`), so the baseline already pipelines at II=1 and is bound by
//! its irregular gather traffic; the split moves the same traffic into the
//! memory kernel and performance stays put.

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty, Val};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen::{self, CsrGraph};

pub struct Color;

pub const SEED: u64 = 0xC010;
pub const SMALL: f32 = -1.0e30;

pub fn graph(scale: Scale) -> CsrGraph {
    match scale {
        Scale::Tiny => datagen::circuit_graph(128, 8, SEED),
        Scale::Small => datagen::circuit_graph(30_000, 12, SEED),
        Scale::Paper => datagen::circuit_graph(1_500_000, 12, SEED),
    }
}

/// Native reference: Jones–Plassmann max rounds; color[v] = round when v's
/// value beats all uncolored neighbours.
pub fn reference(g: &CsrGraph, values: &[f32]) -> Vec<i64> {
    let mut color = vec![-1i64; g.n];
    for round in 0.. {
        let mut any = false;
        let mut decide = vec![];
        for v in 0..g.n {
            if color[v] >= 0 {
                continue;
            }
            any = true;
            let mut mx = SMALL;
            for &u in g.neighbors(v) {
                if color[u as usize] < 0 && u as usize != v {
                    mx = mx.max(values[u as usize]);
                }
            }
            if values[v] > mx {
                decide.push((v, round));
            }
        }
        if !any {
            break;
        }
        for (v, c) in decide {
            color[v] = c;
        }
    }
    color
}

impl Workload for Color {
    fn name(&self) -> &'static str {
        "color"
    }

    fn suite(&self) -> &'static str {
        "Pannotia"
    }

    fn dwarf(&self) -> &'static str {
        "Graph Traversal"
    }

    fn pattern(&self) -> &'static str {
        "Irregular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        format!(
            "circuit-like graph (G3_circuit stand-in), #nodes={}",
            graph(scale).n
        )
    }

    fn dominant(&self) -> &'static str {
        "color_kernel"
    }

    /// Audited benign (ROADMAP vouch audit): within one round, every
    /// conflict read (`color[t2]`, `color[j]` in the gather; `color`,
    /// `node_value`, `node_max` in the assign) targets buffers that are
    /// **read-only for the whole launch** — `color` is advanced only by
    /// the host's `color_next` swap *between* launches, and `node_max` is
    /// written by the gather launch that precedes the assign launch. The
    /// split pairs therefore share no writable buffer at all after DCE
    /// (loads land in the memory kernel, stores in the compute kernel):
    /// the color array is written strictly behind the conflict reads that
    /// decide it, one round later. The syntactic `unit_depth_invariant`
    /// check already accepts every split unit; this vouch records the
    /// semantic argument so the guarantee survives transform changes
    /// (e.g. a future split that keeps a store in the memory kernel) and
    /// extends it to replicated designs, where replicas write disjoint
    /// `t2` slices of `node_max`/`color_next` and the shared `stop` flag
    /// is a monotonic OR.
    fn benign_cross_kernel_races(&self) -> bool {
        true
    }

    fn kernels(&self) -> Vec<Kernel> {
        let gather = KernelBuilder::new("color_kernel", KernelKind::SingleWorkItem)
            .buf_ro("color", Ty::I32)
            .buf_ro("row", Ty::I32)
            .buf_ro("col", Ty::I32)
            .buf_ro("node_value", Ty::F32)
            .buf_wo("node_max", Ty::F32)
            .buf_wo("stop", Ty::I32)
            .scalar("num_nodes", Ty::I32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![if_(
                    ld("color", v("t2")).lt(i(0)),
                    vec![
                        store("stop", i(0), i(1)),
                        let_i("start", ld("row", v("t2"))),
                        let_i("end", ld("row", v("t2") + i(1))),
                        let_f("mx", f(SMALL)),
                        for_(
                            "e",
                            v("start"),
                            v("end"),
                            vec![
                                let_i("j", ld("col", v("e"))),
                                if_(
                                    ld("color", v("j")).lt(i(0)).and(v("j").ne(v("t2"))),
                                    vec![assign("mx", v("mx").max(ld("node_value", v("j"))))],
                                ),
                            ],
                        ),
                        store("node_max", v("t2"), v("mx")),
                    ],
                )],
            )])
            .finish();

        let assign_k = KernelBuilder::new("color_assign", KernelKind::SingleWorkItem)
            .buf_ro("color", Ty::I32)
            .buf_ro("node_value", Ty::F32)
            .buf_ro("node_max", Ty::F32)
            .buf_wo("color_next", Ty::I32)
            .scalar("num_nodes", Ty::I32)
            .scalar("round", Ty::I32)
            .body(vec![for_(
                "t2",
                i(0),
                p("num_nodes"),
                vec![
                    let_i("c", ld("color", v("t2"))),
                    if_else(
                        v("c").lt(i(0)).and(ld("node_value", v("t2")).gt(ld("node_max", v("t2")))),
                        vec![store("color_next", v("t2"), p("round"))],
                        vec![store("color_next", v("t2"), v("c"))],
                    ),
                ],
            )])
            .finish();

        vec![gather, assign_k]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let g = graph(scale);
        let values = datagen::node_values(g.n, SEED ^ 1);
        let mut m = MemoryImage::new();
        m.add_i64s("row", &g.row)
            .add_i64s("col", &g.col)
            .add_f32s("node_value", &values)
            .add_i64s("color", &vec![-1; g.n])
            .add_zeros("color_next", Ty::I32, g.n)
            .add_f32s("node_max", &vec![SMALL; g.n])
            .add_zeros("stop", Ty::I32, 1);
        m.set_i("num_nodes", g.n as i64).set_i("round", 0);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        let n = img.scalar("num_nodes").unwrap().as_i();
        for round in 0..n {
            img.set_scalar("round", Val::I(round));
            img.buf("stop").unwrap().set(0, Val::I(0));
            h.launch(app.unit("color_kernel"), img)?;
            if img.buf("stop").unwrap().get(0).as_i() == 0 {
                break;
            }
            h.launch(app.unit("color_assign"), img)?;
            img.swap_bufs("color", "color_next");
        }
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let g = graph(scale);
        let values = datagen::node_values(g.n, SEED ^ 1);
        let want = reference(&g, &values);
        let got = img.buf("color").unwrap().to_i64s();
        if got != want {
            let ix = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!("color: c[{ix}] = {}, want {}", got[ix], want[ix]));
        }
        // Proper coloring property.
        for v in 0..g.n {
            if got[v] < 0 {
                return Err(format!("color: node {v} uncolored"));
            }
            for &u in g.neighbors(v) {
                if u as usize != v && got[u as usize] == got[v] {
                    return Err(format!("color: adjacent {v},{u} share color {}", got[v]));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn gather_is_not_serialized() {
        let ks = Color.kernels();
        let rep = crate::analysis::report::KernelReport::for_kernel(&ks[0]);
        assert!(rep.loops.iter().all(|l| l.serialized_by.is_none()));
    }

    #[test]
    fn tiny_baseline_and_ff_validate_with_flat_speedup() {
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&Color, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff = run_workload(&Color, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 0.6 && speedup < 1.5, "color ff speedup = {speedup}");
    }
}
