//! Hotspot (Rodinia) — thermal 5-point stencil. The baseline already
//! pipelines at II=1 (all cross-buffer accesses), so the feed-forward
//! split only adds channel overhead: the paper measures 0.85x (Table 2).
//! M2C2 roughly doubles it back (§3: 7340 -> 13660 MB/s, "up to 93%").
//!
//! The kernel updates interior cells; the host replicates the boundary
//! (edge cells keep their temperature) and ping-pongs the two grids.
//! Cross-validated against the Pallas artifact `hotspot.hlo.txt` at Tiny
//! scale by the runtime integration tests.

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen;

pub struct Hotspot;

pub const SEED: u64 = 0x407;

// Rodinia-flavoured constants — keep in sync with python/compile/kernels/hotspot.py.
pub const SDC: f32 = 0.1;
pub const RX: f32 = 0.5;
pub const RY: f32 = 0.4;
pub const RZ: f32 = 0.05;
pub const AMB: f32 = 80.0;

pub fn dims(scale: Scale) -> (usize, usize, usize) {
    // (rows, cols, steps)
    match scale {
        Scale::Tiny => (64, 64, 1), // matches artifacts/hotspot.hlo.txt
        Scale::Small => (256, 256, 4),
        Scale::Paper => (1024, 1024, 8),
    }
}

/// One reference step with edge-replicated boundary (interior formula
/// identical to the kernel; edges treated as their own neighbours).
pub fn reference_step(temp: &[f32], power: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = temp.to_vec();
    let at = |r: i64, c: i64| -> f32 {
        let r = r.clamp(0, rows as i64 - 1) as usize;
        let c = c.clamp(0, cols as i64 - 1) as usize;
        temp[r * cols + c]
    };
    for r in 0..rows as i64 {
        for c in 0..cols as i64 {
            let t = at(r, c);
            let n = at(r - 1, c);
            let s = at(r + 1, c);
            let w = at(r, c - 1);
            let e = at(r, c + 1);
            let pwr = power[(r * cols as i64 + c) as usize];
            out[(r * cols as i64 + c) as usize] = t
                + SDC * (pwr + (n + s - 2.0 * t) * RY + (e + w - 2.0 * t) * RX + (AMB - t) * RZ);
        }
    }
    out
}

/// The device kernel computes interior cells only; the host patches the
/// boundary natively (an O(perimeter) job the real host code also does).
fn patch_boundary(img: &MemoryImage, rows: usize, cols: usize) {
    let temp = img.buf("temp").unwrap();
    let power = img.buf("power").unwrap();
    let result = img.buf("result").unwrap();
    let at = |r: i64, c: i64| -> f32 {
        let r = r.clamp(0, rows as i64 - 1) as usize;
        let c = c.clamp(0, cols as i64 - 1) as usize;
        temp.get(r * cols + c).as_f()
    };
    let cell = |r: usize, c: usize| {
        let (ri, ci) = (r as i64, c as i64);
        let t = at(ri, ci);
        let v = t
            + SDC * (power.get(r * cols + c).as_f()
                + (at(ri - 1, ci) + at(ri + 1, ci) - 2.0 * t) * RY
                + (at(ri, ci - 1) + at(ri, ci + 1) - 2.0 * t) * RX
                + (AMB - t) * RZ);
        result.set(r * cols + c, crate::ir::Val::F(v));
    };
    for c in 0..cols {
        cell(0, c);
        cell(rows - 1, c);
    }
    for r in 0..rows {
        cell(r, 0);
        cell(r, cols - 1);
    }
}

impl Workload for Hotspot {
    fn name(&self) -> &'static str {
        "hotspot"
    }

    fn suite(&self) -> &'static str {
        "Rodinia"
    }

    fn dwarf(&self) -> &'static str {
        "Structured Grid"
    }

    fn pattern(&self) -> &'static str {
        "Regular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        let (r, c, s) = dims(scale);
        format!("{r}x{c} grid, {s} steps")
    }

    fn dominant(&self) -> &'static str {
        "hotspot_kernel"
    }

    fn kernels(&self) -> Vec<Kernel> {
        let idx = || v("r") * p("cols") + v("c2");
        let body = vec![for_(
            "r",
            i(1),
            p("rows") - i(1),
            vec![for_(
                "c2",
                i(1),
                p("cols") - i(1),
                vec![
                    let_f("t", ld("temp", idx())),
                    let_f("tn", ld("temp", idx() - p("cols"))),
                    let_f("ts", ld("temp", idx() + p("cols"))),
                    let_f("tw", ld("temp", idx() - i(1))),
                    let_f("te", ld("temp", idx() + i(1))),
                    let_f("pw", ld("power", idx())),
                    store(
                        "result",
                        idx(),
                        v("t")
                            + p("sdc")
                                * (v("pw")
                                    + (v("tn") + v("ts") - f(2.0) * v("t")) * p("ry")
                                    + (v("te") + v("tw") - f(2.0) * v("t")) * p("rx")
                                    + (p("amb") - v("t")) * p("rz")),
                    ),
                ],
            )],
        )];
        vec![KernelBuilder::new("hotspot_kernel", KernelKind::SingleWorkItem)
            .buf_ro("temp", Ty::F32)
            .buf_ro("power", Ty::F32)
            .buf_wo("result", Ty::F32)
            .scalar("rows", Ty::I32)
            .scalar("cols", Ty::I32)
            .scalar_f("sdc", Ty::F32)
            .scalar_f("rx", Ty::F32)
            .scalar_f("ry", Ty::F32)
            .scalar_f("rz", Ty::F32)
            .scalar_f("amb", Ty::F32)
            .body(body)
            .finish()]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let (rows, cols, _) = dims(scale);
        let (temp, power) = datagen::hotspot_grids(rows, cols, SEED);
        let mut m = MemoryImage::new();
        m.add_f32s("temp", &temp)
            .add_f32s("power", &power)
            .add_zeros("result", Ty::F32, rows * cols);
        m.set_i("rows", rows as i64)
            .set_i("cols", cols as i64)
            .set_f("sdc", SDC)
            .set_f("rx", RX)
            .set_f("ry", RY)
            .set_f("rz", RZ)
            .set_f("amb", AMB);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        let rows = img.scalar("rows").unwrap().as_i() as usize;
        let cols = img.scalar("cols").unwrap().as_i() as usize;
        let (_, _, steps) = dims_for(rows);
        for _ in 0..steps {
            h.launch(app.unit("hotspot_kernel"), img)?;
            patch_boundary(img, rows, cols);
            img.swap_bufs("temp", "result");
        }
        Ok(())
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let (rows, cols, steps) = dims(scale);
        let (mut temp, power) = datagen::hotspot_grids(rows, cols, SEED);
        for _ in 0..steps {
            temp = reference_step(&temp, &power, rows, cols);
        }
        // after the final swap the result lives in "temp"
        let got = img.buf("temp").unwrap().to_f32s();
        for (ix, (g, w)) in got.iter().zip(&temp).enumerate() {
            if (g - w).abs() > 1e-3 {
                return Err(format!("hotspot: temp[{ix}] = {g}, want {w}"));
            }
        }
        Ok(())
    }
}

/// Recover the step count from the runtime grid size (the host driver only
/// sees the image).
fn dims_for(rows: usize) -> (usize, usize, usize) {
    for s in [Scale::Tiny, Scale::Small, Scale::Paper] {
        let d = dims(s);
        if d.0 == rows {
            return d;
        }
    }
    (rows, rows, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::Variant;
    use crate::workloads::run_workload;

    #[test]
    fn baseline_pipelines_at_ii_1() {
        let k = &Hotspot.kernels()[0];
        let rep = crate::analysis::report::KernelReport::for_kernel(k);
        assert_eq!(rep.max_ii(), 1);
        // all five temp loads + power are prefetchable sequential streams
        assert!(rep.prefetching_loads() >= 5, "prefetching = {}", rep.prefetching_loads());
    }

    #[test]
    fn tiny_baseline_validates() {
        let cfg = DeviceConfig::pac_a10();
        run_workload(&Hotspot, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
    }

    #[test]
    fn ff_is_slightly_slower_than_baseline() {
        // The paper's 0.85x: FF adds channel overhead to an already-fine kernel.
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&Hotspot, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff = run_workload(&Hotspot, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 0.7 && speedup < 1.0, "hotspot ff speedup = {speedup}");
    }
}
