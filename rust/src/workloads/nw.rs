//! Needleman–Wunsch (Rodinia, Table 2: 50.95x) — the benchmark that
//! exercises the paper's privatization story (§4.2): the DP recurrence
//! carries a *true* distance-1 MLCD (`m[j]` depends on `m[j-1]` written the
//! previous iteration), so the plain feed-forward split is infeasible; a
//! private carry variable removes it, after which the remaining
//! previous-row loads are false MLCDs the split eliminates.

use super::{App, Harness, Scale, Workload};
use crate::ir::build::*;
use crate::ir::{Kernel, KernelKind, Ty};
use crate::sim::exec::ExecError;
use crate::sim::mem::MemoryImage;
use crate::workloads::datagen;

pub struct Nw;

pub const SEED: u64 = 0x5739;
pub const PENALTY: i64 = 10;

pub fn size(scale: Scale) -> usize {
    match scale {
        Scale::Tiny => 64,
        Scale::Small => 512,
        Scale::Paper => 4096,
    }
}

/// Native DP reference.
pub fn reference(scores: &[i64], n: usize) -> Vec<i64> {
    let mut m = vec![0i64; n * n];
    for j in 0..n {
        m[j] = -(j as i64) * PENALTY;
    }
    for i in 0..n {
        m[i * n] = -(i as i64) * PENALTY;
    }
    for i in 1..n {
        for j in 1..n {
            let diag = m[(i - 1) * n + j - 1] + scores[i * n + j];
            let left = m[i * n + j - 1] - PENALTY;
            let up = m[(i - 1) * n + j] - PENALTY;
            m[i * n + j] = diag.max(left).max(up);
        }
    }
    m
}

impl Workload for Nw {
    fn name(&self) -> &'static str {
        "nw"
    }

    fn suite(&self) -> &'static str {
        "Rodinia"
    }

    fn dwarf(&self) -> &'static str {
        "Dynamic Programming"
    }

    fn pattern(&self) -> &'static str {
        "Regular"
    }

    fn dataset_desc(&self, scale: Scale) -> String {
        let n = size(scale);
        format!("{n}x{n} alignment matrix")
    }

    fn dominant(&self) -> &'static str {
        "nw_kernel"
    }

    fn privatize_first(&self) -> Vec<&'static str> {
        vec!["nw_kernel"]
    }

    fn supports_replication(&self) -> bool {
        // Row i needs row i-1: a replica boundary would read half-written
        // rows. (The single-pair FF version is safe because bounded pipe
        // depth keeps the memory kernel fewer than a row's width ahead of
        // the compute kernel — see the module docs.)
        false
    }

    fn kernels(&self) -> Vec<Kernel> {
        let idx = || v("i3") * p("n") + v("j3");
        let body = vec![for_(
            "i3",
            i(1),
            p("n"),
            vec![for_(
                "j3",
                i(1),
                p("n"),
                vec![
                    let_i("diag", ld("m", idx() - p("n") - i(1)) + ld("s", idx())),
                    // the true distance-1 dependency the paper privatizes:
                    let_i("left", ld("m", idx() - i(1)) - p("penalty")),
                    let_i("up", ld("m", idx() - p("n")) - p("penalty")),
                    store("m", idx(), v("diag").max(v("left")).max(v("up"))),
                ],
            )],
        )];
        vec![KernelBuilder::new("nw_kernel", KernelKind::SingleWorkItem)
            .buf_rw("m", Ty::I32)
            .buf_ro("s", Ty::I32)
            .scalar("n", Ty::I32)
            .scalar("penalty", Ty::I32)
            .body(body)
            .finish()]
    }

    fn image(&self, scale: Scale) -> MemoryImage {
        let n = size(scale);
        let mut m0 = vec![0i64; n * n];
        for j in 0..n {
            m0[j] = -(j as i64) * PENALTY;
        }
        for i2 in 0..n {
            m0[i2 * n] = -(i2 as i64) * PENALTY;
        }
        let mut m = MemoryImage::new();
        m.add_i64s("m", &m0).add_i64s("s", &datagen::nw_scores(n, SEED));
        m.set_i("n", n as i64).set_i("penalty", PENALTY);
        m
    }

    fn run(&self, app: &App, img: &mut MemoryImage, h: &mut Harness) -> Result<(), ExecError> {
        h.launch(app.unit("nw_kernel"), img)
    }

    fn validate(&self, img: &MemoryImage, scale: Scale) -> Result<(), String> {
        let n = size(scale);
        let want = reference(&datagen::nw_scores(n, SEED), n);
        let got = img.buf("m").unwrap().to_i64s();
        if got != want {
            let ix = got.iter().zip(&want).position(|(a, b)| a != b).unwrap();
            return Err(format!("nw: m[{ix}] = {}, want {}", got[ix], want[ix]));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::DeviceConfig;
    use crate::transform::{check_feasible, privatize, Variant};
    use crate::workloads::run_workload;

    #[test]
    fn baseline_has_true_mlcd_until_privatized() {
        let k = &Nw.kernels()[0];
        assert!(check_feasible(k).is_err());
        let pk = privatize(k).unwrap();
        assert!(check_feasible(&pk).is_ok());
    }

    #[test]
    fn plain_feedforward_is_rejected() {
        // Without privatization the split must refuse (paper §3 limits).
        let k = &Nw.kernels()[0];
        assert!(crate::transform::feedforward(k, 1).is_err());
    }

    #[test]
    fn tiny_baseline_validates() {
        let cfg = DeviceConfig::pac_a10();
        run_workload(&Nw, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
    }

    #[test]
    fn tiny_ff_validates_with_big_speedup() {
        let cfg = DeviceConfig::pac_a10();
        let base = run_workload(&Nw, Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let ff = run_workload(&Nw, Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
        let speedup = base.metrics.seconds / ff.metrics.seconds;
        assert!(speedup > 10.0, "nw tiny ff speedup = {speedup}");
    }
}
