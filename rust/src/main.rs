//! `pipefwd` CLI — the leader entrypoint.
//!
//! Subcommands regenerate the paper's tables/figures, print compiler
//! reports and transformed source, validate against the PJRT golden
//! artifacts, and drive the experiment engine — locally through the
//! `coordinator::Service` facade (`run`, `sweep`, `tune`, `merge`,
//! `store`), as a daemon (`serve`), or as a client of one (`client`).
//! Std-only argument parsing (no clap in this offline image): one
//! declarative spec table shared by every subcommand, with the same
//! validators the daemon's wire decoder uses.

use pipefwd::coordinator::{
    self, net, service, Engine, Mode, Service, ServiceRequest, ServiceResponse, Store,
};
use pipefwd::sim::device::{DeviceConfig, DeviceRegistry};
use pipefwd::transform::Variant;
use pipefwd::util::json;
use pipefwd::workloads::{by_name, Scale};
use std::path::Path;
use std::sync::Arc;

const USAGE: &str = "\
pipefwd — feed-forward design model for OpenCL kernels via pipes
          (simulated-FPGA reproduction; see DESIGN.md)

USAGE: pipefwd <command> [--scale tiny|small|paper] [--csv] [--jobs N]

ENGINE COMMANDS (parallel, cache-aware, persistent):
  run --experiment E1..E9|all   run experiments through the engine and
      [--shard I/N] [--des]     write the BENCH_PR1.json results sink;
      [--device NAME|all]       --shard computes one disjoint grid slice;
      [--overlap]               --device all fans out across the device
                                registry in parallel (one worker per
                                profile, one sink per device) and
                                stitches the E8 cross-device table
  sweep [--depths 1,100,1000]   channel-depth sweep over arbitrary depths
        [--benches fw,hotspot,mis]
  tune --benches LIST           autotune (pipe depth x replication) per
       [--policy golden|sh]     workload with a budgeted search instead
       [--budget 40]            of an exhaustive grid; renders a
       [--replication]          TuneReport table and writes TUNE.json
       [--no-ref]               (--out overrides the path)
  merge <dir>...                union shard stores and emit the canonical
                                BENCH_PR1.json (byte-identical to serial)
  report [--format table|json]  re-render a results sink (default:
         [--in BENCH_PR1.json]  BENCH_PR1.json; if the default file is
                                absent, renders from the persistent store)
  report --diff <old> <new>     compare two results sinks (exit 1 on
         [--threshold PCT]      modelled regressions > PCT %) or two
                                counters documents (informational)
  store stats                   per-tier store footprint (entries /
        [--format table|json]   traces / pooled profiles, plus the
                                journal/droppings overhead tier), the
                                profile pool's dedup ratio, and the
                                budget-governed byte total
  store gc [--dry-run]          delete every store record unreachable
                                from the current E1-E9 grids (all scales,
                                all registry devices, both estimators)
                                and the tuner's
                                depth x replication ladders, plus pooled
                                profiles no surviving trace references;
                                rewrites MANIFEST.json (--dry-run only
                                reports)

DAEMON COMMANDS (measurement as a service, schema pipefwd-api-v1):
  serve --addr HOST:PORT        serve measure/sweep/tune/store requests
        [--workers N]           to many concurrent clients over TCP/HTTP;
        [--queue N]             shared cells dedup through one engine's
        [--token T]             claim/fulfil memo; bounded request queue
                                answers 503 + Retry-After when full;
                                GET /stats for live counters + store
                                footprint, GET /healthz and /readyz for
                                probes, POST /shutdown for graceful
                                drain; --token requires Authorization:
                                Bearer from non-loopback peers
  client <action>               drive a daemon from the same binary:
        [--addr HOST:PORT]      run | sweep | tune | stats | store-pull
        [--token T]             | store-push — sinks are reassembled
        [--deadline-ms N]       byte-identical to the serial CLI path;
                                transient failures (503, resets,
                                truncated streams) retry with capped
                                exponential backoff; store-push uploads
                                the local store's records for server-side
                                verification (see docs/RELIABILITY.md)

TABLE COMMANDS:
  table1               benchmark characterisation (paper Table 1)
  table2               feed-forward vs baseline (paper Table 2)
  figure4              M2C2 speedup + overhead (paper Figure 4)
  table3               microbenchmarks (paper Table 3)
  intext               II / bandwidth numbers quoted in the text (E4a/b)
  sweeps               channel-depth + producer/consumer sweeps (E4c/d)
  vectors              vector-type case study (E4e)
  micro-family         extended microbenchmark family (future work)
  headline             the paper's headline speedup claims (E7)
  all                  everything above, in order
  report-kernel <b>    early-stage compiler report, baseline vs FF (E4a)
  source <bench>       OpenCL-flavoured source, baseline and FF kernels
  golden               validate IR numerics against PJRT artifacts
  list                 list benchmarks

OPTIONS:
  --scale S        dataset scale (default: small; tiny = artifact-matched)
  --csv            also write results/<name>.csv
  --jobs N         engine worker threads (default: all cores)
  --out PATH       results-sink path for `run`/`sweep`/`merge`
                   (default: BENCH_PR1.json)
  --experiment E   comma-separated experiment ids (E1..E9 or all)
  --device D       device profile to model: arria10 (default),
                   stratix10-hbm, gpu-like, cpu-like (see docs/DEVICES.md
                   for the calibrations); `run` also accepts `all` to
                   sweep the whole registry — per-device sinks plus one
                   stitched E8 cross-device portability table. Every
                   profile shares the device-free trace tier, so a
                   cross-device sweep pays the interpreter once.
  --depths LIST    comma-separated pipe depths for `sweep` (sorted and
                   deduplicated; duplicate columns would break the
                   deterministic-output guarantees)
  --benches LIST   comma-separated benchmarks for `sweep`/`tune`
                   (validated against the workload registry at parse time)
  --policy P       search policy for `tune`/`--tuned`: golden
                   (golden-section over log-depth) or sh (successive
                   halving over depth x replication, cheap scales first);
                   default: the device profile's declared policy
                   (arria10: golden)
  --budget N       max distinct probes a search may spend — on a cold
                   store, the max simulations; default: the device
                   profile's declared budget (arria10: 40)
  --replication    include replication factors m2c2..m4c4 in the tuned
                   configuration space
  --no-ref         skip the TuneReport's exhaustive-reference column
                   (the regret baseline costs the full grid once)
  --dry-run        `store gc`: report what would be deleted without
                   touching the store (not even the manifest)
  --tuned          `run`/`sweep`: let the tuner pick best-ff depths for
                   the E1/E2/E7 tables and annotate the E4 depth sweep
  --format F       `report` output: table (default) or json
  --in PATH        `report` input file (default: BENCH_PR1.json)
  --diff OLD NEW   `report` diff mode: two results sinks (or counters
                   documents, v1/v2/v3) to compare
  --threshold PCT  regression threshold for `report --diff` (default: 5)
  --shard I/N      compute only shard I of N (1-based) of the unique
                   experiment grid; merge the stores afterwards
  --cache-dir DIR  persistent measurement store directory
                   (default: $PIPEFWD_CACHE_DIR or .pipefwd-cache)
  --no-cache       do not read or write the persistent store
  --max-bytes B    byte budget for the persistent store (or
                   $PIPEFWD_MAX_BYTES; k/m/g suffixes accepted): puts
                   past the budget evict coldest-first under a journaled
                   batch — pinned in-flight keys and pool files live
                   traces reference survive; a budget too tight for even
                   one record degrades to write-through-skip (counted in
                   store_budget_skips) instead of thrashing
  --des            estimate with the discrete-event simulator instead of
                   the analytic model (cached under a distinct key)
  --overlap        schedule launch *graphs* instead of launch chains:
                   analysis::deps builds the launch-dependence DAG,
                   transform::task_sequence folds it into wavefronts, and
                   the graph DES co-schedules each wavefront over shared
                   memory (MKPipe-style multi-kernel overlap). Cached
                   under keys carrying a trailing `overlap=on` line, so
                   overlap-off artifacts stay byte-identical
  --counters PATH  after `run`/`sweep`/`tune`/`serve`, write the engine
                   counters to a pipefwd-counters-v3 document: the engine
                   tiers (trace_hits/trace_runs/store_hits/simulations/
                   cache_hits) plus the daemon counters (queue_depth_max/
                   clients_served/requests_deduped, zero in CLI mode)
                   and the reliability counters (retries/journal_replays/
                   store_degraded) and wall-clock — CI gates on a warm
                   rerun reporting zero trace runs
  --addr H:P       daemon address for `serve`/`client`
                   (default: 127.0.0.1:7341)
  --workers N      `serve`: connection-handling worker threads (default 4)
  --queue N        `serve`: bounded request-queue capacity — when full
                   the daemon answers 503 instead of buffering (default 64)
  --token T        shared-secret auth for `serve`/`client` (or
                   $PIPEFWD_TOKEN): a serving daemon answers 401 unless
                   non-loopback requests carry Authorization: Bearer T
                   (constant-time compared; loopback peers are exempt
                   unless --token-all; /healthz + /readyz never require it)
  --token-all      `serve`: require the token from loopback peers too
  --client-cap N   `serve`: fair-share cap — the most requests one
                   client (keyed by token, else non-loopback peer IP)
                   may have in flight at once; default: workers - 1
                   (anonymous loopback peers are exempt)
  --deadline-ms N  `client`: declare a freshness deadline on every
                   request; the daemon sheds the request with 503 +
                   Retry-After before doing any work if it waited in
                   the accept queue longer than this (absent = wait
                   indefinitely, the pre-PR-10 behavior)
  --fault-plan S   deterministic fault injection for robustness testing
                   (or $PIPEFWD_FAULT_PLAN): a seeded schedule like
                   `seed=42;store.write=0.25x4;net.read=0.1` over the
                   named IO/network sites — see docs/RELIABILITY.md.
                   Empty/absent = zero overhead, byte-identical behavior
";

fn fail(msg: &str) -> ! {
    eprintln!("{msg}");
    std::process::exit(2);
}

// ---------------------------------------------------------------------------
// Declarative argument parsing: one spec table for every subcommand.
// Validation happens at parse time through the same `service::*_from`
// parsers the daemon's wire decoder uses, so a bad value produces the
// same message whether it arrives via argv or via a pipefwd-api-v1
// request document.
// ---------------------------------------------------------------------------

struct ArgSpec {
    name: &'static str,
    /// Values the flag consumes (0 = boolean flag, 2 = `--diff OLD NEW`).
    arity: usize,
    /// Parse-time validator for each consumed value.
    validate: Option<fn(&str) -> Result<(), String>>,
}

fn v_scale(v: &str) -> Result<(), String> {
    service::scale_from(v).map(|_| ())
}
fn v_posint(v: &str) -> Result<(), String> {
    service::posint_from(v).map(|_| ())
}
fn v_experiments(v: &str) -> Result<(), String> {
    service::experiments_from(v).map(|_| ())
}
fn v_depths(v: &str) -> Result<(), String> {
    service::depths_from(v).map(|_| ())
}
fn v_benches(v: &str) -> Result<(), String> {
    service::benches_from(v).map(|_| ())
}
fn v_policy(v: &str) -> Result<(), String> {
    service::policy_from(v).map(|_| ())
}
fn v_shard(v: &str) -> Result<(), String> {
    service::shard_from(v).map(|_| ())
}
fn v_device(v: &str) -> Result<(), String> {
    // `all` is CLI-only fan-out sugar (rejected on the wire by
    // `service::device_from`); whether the subcommand accepts it is
    // checked after parsing, where the command is known.
    if v == "all" {
        return Ok(());
    }
    service::device_from(v).map(|_| ())
}
fn v_threshold(v: &str) -> Result<(), String> {
    service::threshold_from(v).map(|_| ())
}
fn v_addr(v: &str) -> Result<(), String> {
    service::addr_from(v).map(|_| ())
}
fn v_format(v: &str) -> Result<(), String> {
    if v == "table" || v == "json" {
        Ok(())
    } else {
        Err(format!("unknown format `{v}` (table|json)"))
    }
}
fn v_fault_plan(v: &str) -> Result<(), String> {
    pipefwd::util::fault::FaultPlan::parse(v).map(|_| ())
}
fn v_max_bytes(v: &str) -> Result<(), String> {
    pipefwd::coordinator::store::parse_byte_budget(v).map(|_| ())
}

const ARG_SPECS: &[ArgSpec] = &[
    ArgSpec { name: "--scale", arity: 1, validate: Some(v_scale) },
    ArgSpec { name: "--csv", arity: 0, validate: None },
    ArgSpec { name: "--jobs", arity: 1, validate: Some(v_posint) },
    ArgSpec { name: "--experiment", arity: 1, validate: Some(v_experiments) },
    ArgSpec { name: "--depths", arity: 1, validate: Some(v_depths) },
    ArgSpec { name: "--benches", arity: 1, validate: Some(v_benches) },
    ArgSpec { name: "--policy", arity: 1, validate: Some(v_policy) },
    ArgSpec { name: "--budget", arity: 1, validate: Some(v_posint) },
    ArgSpec { name: "--replication", arity: 0, validate: None },
    ArgSpec { name: "--dry-run", arity: 0, validate: None },
    ArgSpec { name: "--no-ref", arity: 0, validate: None },
    ArgSpec { name: "--tuned", arity: 0, validate: None },
    ArgSpec { name: "--out", arity: 1, validate: None },
    ArgSpec { name: "--in", arity: 1, validate: None },
    ArgSpec { name: "--format", arity: 1, validate: Some(v_format) },
    ArgSpec { name: "--shard", arity: 1, validate: Some(v_shard) },
    ArgSpec { name: "--device", arity: 1, validate: Some(v_device) },
    ArgSpec { name: "--cache-dir", arity: 1, validate: None },
    ArgSpec { name: "--no-cache", arity: 0, validate: None },
    ArgSpec { name: "--des", arity: 0, validate: None },
    ArgSpec { name: "--overlap", arity: 0, validate: None },
    ArgSpec { name: "--counters", arity: 1, validate: None },
    ArgSpec { name: "--diff", arity: 2, validate: None },
    ArgSpec { name: "--threshold", arity: 1, validate: Some(v_threshold) },
    ArgSpec { name: "--addr", arity: 1, validate: Some(v_addr) },
    ArgSpec { name: "--workers", arity: 1, validate: Some(v_posint) },
    ArgSpec { name: "--queue", arity: 1, validate: Some(v_posint) },
    ArgSpec { name: "--token", arity: 1, validate: None },
    ArgSpec { name: "--token-all", arity: 0, validate: None },
    ArgSpec { name: "--fault-plan", arity: 1, validate: Some(v_fault_plan) },
    ArgSpec { name: "--max-bytes", arity: 1, validate: Some(v_max_bytes) },
    ArgSpec { name: "--client-cap", arity: 1, validate: Some(v_posint) },
    ArgSpec { name: "--deadline-ms", arity: 1, validate: Some(v_posint) },
];

struct Args {
    values: std::collections::HashMap<&'static str, Vec<String>>,
    positional: Vec<String>,
}

impl Args {
    fn parse(raw: &[String]) -> Args {
        let mut values: std::collections::HashMap<&'static str, Vec<String>> =
            std::collections::HashMap::new();
        let mut positional = vec![];
        let mut it = raw.iter();
        while let Some(a) = it.next() {
            if let Some(spec) = ARG_SPECS.iter().find(|s| s.name == a.as_str()) {
                let mut vals = vec![];
                for _ in 0..spec.arity {
                    let v = it
                        .next()
                        .unwrap_or_else(|| fail(&format!("{}: expected a value", spec.name)));
                    if let Some(validate) = spec.validate {
                        if let Err(e) = validate(v) {
                            fail(&format!("{}: {e}", spec.name));
                        }
                    }
                    vals.push(v.clone());
                }
                values.insert(spec.name, vals); // last occurrence wins
            } else if a.starts_with("--") {
                fail(&format!("unknown flag `{a}` (see `pipefwd` usage)"));
            } else {
                positional.push(a.clone());
            }
        }
        Args { values, positional }
    }

    fn flag(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    fn value(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.first()).map(String::as_str)
    }

    fn pair(&self, name: &str) -> Option<(&str, &str)> {
        let v = self.values.get(name)?;
        Some((v[0].as_str(), v[1].as_str()))
    }
}

/// Unwrap a validated value (parse-time validation means this cannot
/// fire for table-spec'd flags, but the message stays consistent).
fn req<T>(name: &str, r: Result<T, String>) -> T {
    r.unwrap_or_else(|e| fail(&format!("{name}: {e}")))
}

/// Suffix an artifact path with a device name — `BENCH_PR1.json` +
/// `stratix10-hbm` → `BENCH_PR1.stratix10-hbm.json` — so a
/// `--device all` run writes one sink (and counters document) per
/// registry profile instead of each device clobbering the last.
fn device_path(base: &str, device: &str) -> String {
    match base.rsplit_once('.') {
        Some((stem, ext)) if !stem.is_empty() => format!("{stem}.{device}.{ext}"),
        _ => format!("{base}.{device}"),
    }
}

fn main() {
    let wall_start = std::time::Instant::now();
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = raw[0].as_str();
    let args = Args::parse(&raw[1..]);

    // Arm fault injection (--fault-plan or $PIPEFWD_FAULT_PLAN) before
    // any store/engine/daemon construction, so open-time healing and
    // every IO seam run under the schedule. Absent plan = disarmed fast
    // path, byte-identical behavior.
    if let Err(e) = pipefwd::util::fault::install_from(args.value("--fault-plan")) {
        fail(&format!("--fault-plan: {e}"));
    }

    let scale = args
        .value("--scale")
        .map(|v| req("--scale", service::scale_from(v)))
        .unwrap_or(Scale::Small);
    let csv = args.flag("--csv");
    let jobs = args
        .value("--jobs")
        .map(|v| req("--jobs", service::posint_from(v)))
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    let experiment = args.value("--experiment").unwrap_or("all").to_string();
    let depths: Vec<usize> = args
        .value("--depths")
        .map(|v| req("--depths", service::depths_from(v)))
        .unwrap_or_else(|| vec![1, 100, 1000]);
    let benches: Vec<String> = args
        .value("--benches")
        .map(|v| req("--benches", service::benches_from(v)))
        .unwrap_or_else(|| vec!["fw".into(), "hotspot".into(), "mis".into()]);
    let policy_flag = args.value("--policy").map(|v| req("--policy", service::policy_from(v)));
    let budget_flag = args.value("--budget").map(|v| req("--budget", service::posint_from(v)));
    let replication = args.flag("--replication");
    let dry_run = args.flag("--dry-run");
    let no_ref = args.flag("--no-ref");
    let tuned = args.flag("--tuned");
    let out_set = args.flag("--out");
    let out_path = args.value("--out").unwrap_or("BENCH_PR1.json").to_string();
    let in_set = args.flag("--in");
    let in_path = args.value("--in").unwrap_or("BENCH_PR1.json").to_string();
    let format = args.value("--format").unwrap_or("table").to_string();
    let shard = args.value("--shard").map(|v| req("--shard", service::shard_from(v)));
    // `device_flag` keeps the tri-state: absent (None, wire-compatible
    // with pre-device daemons), an explicit name, or `all` (run-only).
    let device_flag = args.value("--device").map(String::from);
    let device_all = device_flag.as_deref() == Some("all");
    let cache_dir = args.value("--cache-dir").map(String::from);
    let no_cache = args.flag("--no-cache");
    let use_des = args.flag("--des");
    let overlap = args.flag("--overlap");
    let counters_path = args.value("--counters").map(String::from);
    let threshold = args
        .value("--threshold")
        .map(|v| req("--threshold", service::threshold_from(v)))
        .unwrap_or(5.0);
    let addr = args
        .value("--addr")
        .map(|v| req("--addr", service::addr_from(v)))
        .unwrap_or_else(|| "127.0.0.1:7341".to_string());
    let workers = args
        .value("--workers")
        .map(|v| req("--workers", service::posint_from(v)))
        .unwrap_or(4);
    let queue_cap = args
        .value("--queue")
        .map(|v| req("--queue", service::posint_from(v)))
        .unwrap_or(64);
    let token = args
        .value("--token")
        .map(String::from)
        .or_else(|| std::env::var("PIPEFWD_TOKEN").ok().filter(|t| !t.is_empty()));
    let token_all = args.flag("--token-all");
    let client_cap = args
        .value("--client-cap")
        .map(|v| req("--client-cap", service::posint_from(v)))
        .unwrap_or(0); // 0 = auto: max(1, workers - 1)
    let deadline_ms = args
        .value("--deadline-ms")
        .map(|v| req("--deadline-ms", service::posint_from(v)) as u64);
    let max_bytes = Store::resolve_max_bytes(args.value("--max-bytes"))
        .unwrap_or_else(|e| fail(&format!("--max-bytes: {e}")));
    let positional = &args.positional;

    if device_all && cmd != "run" {
        fail("--device all: only `run` fans out across the device registry (name one device)");
    }
    // Resolve the device profile every single-device code path models
    // (default: arria10, the calibration all pre-device-zoo artifacts
    // were measured on). `run --device all` ignores this and builds one
    // engine per registry profile instead.
    let cfg = if device_all {
        DeviceConfig::pac_a10()
    } else {
        let name = device_flag.as_deref().unwrap_or("arria10");
        pipefwd::sim::device::by_name(name)
            .unwrap_or_else(|| fail(&format!("--device: unknown device `{name}`")))
    };
    // Tuner defaults (the PR-8 follow-up): when --policy/--budget are
    // absent, the resolved device profile's declared defaults apply.
    // arria10 declares golden/40 — the historical hardcoded CLI
    // defaults — so existing invocations are bit-identical.
    let policy = policy_flag
        .unwrap_or_else(|| req("--policy", service::policy_from(cfg.tune_policy)));
    let budget = budget_flag.unwrap_or(cfg.tune_budget);

    // The persistent store every engine command reads through / writes
    // behind (tentpole of PR 2); `--no-cache` restores PR-1 behavior.
    let open_store = || -> Option<Store> {
        if no_cache {
            return None;
        }
        let dir = Store::resolve_dir(cache_dir.as_deref());
        match Store::open(&dir) {
            // arming the budget runs one eviction pass, so a store
            // opened over budget (or under a newly lowered budget) is
            // trimmed before any new work lands
            Ok(s) => Some(s.with_max_bytes(max_bytes)),
            Err(e) => {
                eprintln!("warning: cannot open store {}: {e} (running uncached)", dir.display());
                None
            }
        }
    };
    // Every engine command talks to the same `Service` facade the daemon
    // serves — the CLI is just a local client of it. The caller names the
    // device so `run --device all` can build one service per profile.
    let mk_service = |dev: DeviceConfig, jobs: usize, mode: Mode| -> Service {
        let mut e = Engine::new(dev, jobs).with_des(use_des).with_overlap(overlap);
        if let Some(s) = open_store() {
            e = e.with_store(s);
        }
        if tuned {
            e = e.with_tuner(coordinator::TuneSpec { policy, budget });
        }
        Service::new(e, mode)
    };
    // `--counters PATH`: the service's tier counters + wall clock as one
    // machine-readable pipefwd-counters-v2 document per invocation. CI's
    // warm-rerun gate reads `trace_runs`/`simulations` from here.
    let write_counters = |svc: &Service, command: &str| {
        let Some(path) = counters_path.as_deref() else { return };
        let doc = svc.counters_doc(
            command,
            coordinator::scale_label(scale),
            wall_start.elapsed().as_millis() as f64,
        );
        match json::write_file_atomic(Path::new(path), &doc) {
            Ok(()) => eprintln!("wrote {path}"),
            Err(e) => fail(&format!("writing {path}: {e}")),
        }
    };
    let finish_engine = |engine: &Engine| {
        if let Some(s) = engine.store() {
            if let Err(e) = s.write_manifest() {
                eprintln!("warning: writing store manifest: {e}");
            }
        }
    };

    let save = |t: &pipefwd::report::Table, name: &str| {
        print!("{}", t.to_markdown());
        if csv {
            match t.save_csv(name) {
                Ok(p) => eprintln!("wrote {}", p.display()),
                Err(e) => eprintln!("csv write failed: {e}"),
            }
        }
    };

    match cmd {
        "list" => {
            for w in pipefwd::workloads::suite() {
                println!("{:>10}  {:8}  {}", w.name(), w.suite(), w.dataset_desc(scale));
            }
        }
        "run" => {
            let exps = req("--experiment", service::experiments_from(&experiment));
            if device_all {
                if shard.is_some() {
                    fail("--device all cannot combine with --shard: shard one device at a \
                          time, then merge");
                }
                // One engine per registry profile, all sharing the same
                // store directory: measurement keys are per-device but
                // the trace tier is device-free, so at most one engine
                // pays the interpreter per trace (concurrent writers are
                // harmless — atomic writes of identical bytes). The
                // profiles are independent, so they measure in parallel:
                // one worker thread per device, each engine sized to its
                // share of --jobs. Workers never exit the process — any
                // failure is carried out of the scope (joined in registry
                // order) and reported once, so output stays deterministic.
                let devices = DeviceRegistry::all();
                let dev_jobs = (jobs / devices.len()).max(1);
                let svcs: Vec<Service> = std::thread::scope(|s| {
                    let handles: Vec<_> = devices
                        .iter()
                        .map(|dev| {
                            let exps = exps.clone();
                            let mk = &mk_service;
                            s.spawn(move || -> Result<Service, String> {
                                let svc = mk(dev.clone(), dev_jobs, Mode::Cli);
                                svc.handle(&ServiceRequest::Run {
                                    experiments: exps,
                                    scale,
                                    shard: None,
                                    device: Some(dev.name.to_string()),
                                })
                                .map_err(|e| {
                                    format!("run --device {}: {}", dev.name, e.render())
                                })?;
                                Ok(svc)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| {
                            h.join().unwrap_or_else(|_| {
                                Err("run --device all: a device worker panicked".into())
                            })
                        })
                        .collect::<Result<Vec<_>, String>>()
                })
                .unwrap_or_else(|e| fail(&e));
                for svc in &svcs {
                    let engine = svc.engine();
                    let dev = engine.cfg.name;
                    let sink = device_path(&out_path, dev);
                    match engine.write_bench_json(Path::new(&sink), scale, &exps) {
                        Ok(()) => eprintln!(
                            "wrote {sink} ({dev}: {} measurements, {} simulated, \
                             {} trace runs, {} trace hits, {} store hits)",
                            engine.measurements().len(),
                            engine.simulations(),
                            engine.trace_runs(),
                            engine.trace_hits(),
                            engine.store_hits(),
                        ),
                        Err(e) => fail(&format!("writing {sink}: {e}")),
                    }
                    if let Some(cpath) = counters_path.as_deref() {
                        let doc = svc.counters_doc(
                            "run",
                            coordinator::scale_label(scale),
                            wall_start.elapsed().as_millis() as f64,
                        );
                        let cpath = device_path(cpath, dev);
                        match json::write_file_atomic(Path::new(&cpath), &doc) {
                            Ok(()) => eprintln!("wrote {cpath}"),
                            Err(e) => fail(&format!("writing {cpath}: {e}")),
                        }
                    }
                    finish_engine(engine);
                }
                let engines: Vec<&Engine> = svcs.iter().map(|s| s.engine()).collect();
                save(&coordinator::cross_device_table(&engines, scale), "e8_cross_device");
                return;
            }
            let svc = mk_service(cfg.clone(), jobs, Mode::Cli);
            let resp = svc
                .handle(&ServiceRequest::Run {
                    experiments: exps.clone(),
                    scale,
                    shard,
                    device: device_flag.clone(),
                })
                .unwrap_or_else(|e| fail(&e.render()));
            let engine = svc.engine();
            if let Some((index, count)) = shard {
                let ServiceResponse::Cells { grid_cells, cells } = &resp else {
                    fail("run: unexpected response kind")
                };
                eprintln!(
                    "shard {index}/{count}: {} of {} unique cells, {} simulated \
                     ({} trace runs, {} trace hits), {} store hits",
                    cells.len(),
                    grid_cells,
                    engine.simulations(),
                    engine.trace_runs(),
                    engine.trace_hits(),
                    engine.store_hits(),
                );
            } else {
                // the facade already measured the grid; the table
                // renderers replay it from the warm memo table
                for exp in &exps {
                    for (i, t) in engine.run_experiment(*exp, scale).iter().enumerate() {
                        save(t, &format!("{}_{i}", exp.label().to_lowercase()));
                        println!();
                    }
                }
            }
            // A shard's product is its store entries; a partial sink under
            // the default name would masquerade as a complete one (and
            // concurrent shards would race on it), so shards only write a
            // sink to an explicit --out.
            if shard.is_none() || out_set {
                match engine.write_bench_json(Path::new(&out_path), scale, &exps) {
                    Ok(()) => eprintln!(
                        "wrote {out_path} ({} measurements, {} unique configs, {} cache hits, \
                         {} store hits, {} simulated, {} trace runs, {} trace hits, {jobs} jobs)",
                        engine.measurements().len(),
                        engine.cache_len(),
                        engine.cache_hits(),
                        engine.store_hits(),
                        engine.simulations(),
                        engine.trace_runs(),
                        engine.trace_hits(),
                    ),
                    Err(e) => fail(&format!("writing {out_path}: {e}")),
                }
            }
            write_counters(&svc, "run");
            finish_engine(engine);
        }
        "merge" => {
            if positional.is_empty() {
                fail("merge <dir>... (at least one shard store directory)");
            }
            let exps = req("--experiment", service::experiments_from(&experiment));
            let svc = mk_service(cfg.clone(), 1, Mode::Cli);
            let resp = svc
                .handle(&ServiceRequest::Merge {
                    dirs: positional.clone(),
                    experiments: exps,
                    scale,
                })
                .unwrap_or_else(|e| fail(&e.render()));
            let ServiceResponse::Merged { imported, bench } = resp else {
                fail("merge: unexpected response kind")
            };
            if let Some(local) = svc.engine().store() {
                eprintln!(
                    "imported {imported} new records (measurement + trace tiers) into {}",
                    local.root().display()
                );
            }
            match std::fs::write(&out_path, &bench) {
                Ok(()) => {
                    eprintln!("wrote {out_path} (merged from {} store(s))", positional.len());
                }
                Err(e) => fail(&format!("writing {out_path}: {e}")),
            }
        }
        "sweep" => {
            let svc = mk_service(cfg.clone(), jobs, Mode::Cli);
            if let Err(e) = svc.handle(&ServiceRequest::Sweep {
                benches: benches.clone(),
                depths: depths.clone(),
                scale,
                device: device_flag.clone(),
            }) {
                fail(&e.render());
            }
            let engine = svc.engine();
            let names: Vec<&str> = benches.iter().map(|b| b.as_str()).collect();
            save(&engine.depth_sweep(&names, scale, &depths), "depth_sweep");
            match engine.write_bench_json(Path::new(&out_path), scale, &[]) {
                Ok(()) => eprintln!(
                    "wrote {out_path} ({} simulated, {} trace runs, {} trace hits)",
                    engine.simulations(),
                    engine.trace_runs(),
                    engine.trace_hits(),
                ),
                Err(e) => fail(&format!("writing {out_path}: {e}")),
            }
            write_counters(&svc, "sweep");
            finish_engine(engine);
        }
        "tune" => {
            let svc = mk_service(cfg.clone(), jobs, Mode::Cli);
            let resp = svc
                .handle(&ServiceRequest::Tune {
                    benches: benches.clone(),
                    policy,
                    budget,
                    replication,
                    scale,
                    reference: !no_ref,
                    device: device_flag.clone(),
                })
                .unwrap_or_else(|e| fail(&e.render()));
            let ServiceResponse::Tune { report } = resp else {
                fail("tune: unexpected response kind")
            };
            save(&report.table(), "tune");
            let engine = svc.engine();
            // the TuneReport artifact deliberately excludes live counters,
            // so a warm-store rerun is byte-identical to the cold run
            let tune_path = if out_set { out_path.clone() } else { "TUNE.json".to_string() };
            match json::write_file_atomic(Path::new(&tune_path), &report.to_json()) {
                Ok(()) => eprintln!(
                    "wrote {tune_path} ({} bench(es), {} policy, {} probes, \
                     simulations: {}, trace runs: {}, trace hits: {}, store hits: {})",
                    report.outcomes.len(),
                    report.policy.label(),
                    report.total_probes(),
                    engine.simulations(),
                    engine.trace_runs(),
                    engine.trace_hits(),
                    engine.store_hits(),
                ),
                Err(e) => fail(&format!("writing {tune_path}: {e}")),
            }
            write_counters(&svc, "tune");
            finish_engine(engine);
        }
        "serve" => {
            let svc = Arc::new(mk_service(cfg.clone(), jobs, Mode::Daemon));
            let store_desc = svc
                .engine()
                .store()
                .map(|s| s.root().display().to_string())
                .unwrap_or_else(|| "none".to_string());
            let auth_desc = match (&token, token_all) {
                (Some(_), true) => "token (all peers)",
                (Some(_), false) => "token (non-loopback)",
                (None, _) => "none",
            };
            let budget_desc = match max_bytes {
                Some(b) => format!("{b} bytes"),
                None => "unbounded".to_string(),
            };
            let server = net::Server::spawn(
                Arc::clone(&svc),
                &addr,
                net::ServerConfig {
                    workers,
                    queue_cap,
                    token: token.clone(),
                    token_all,
                    per_client_cap: client_cap,
                },
            )
            .unwrap_or_else(|e| fail(&format!("serve: binding {addr}: {e}")));
            eprintln!(
                "pipefwd serve: listening on {} (device {}, {jobs} engine jobs, \
                 {workers} workers, queue {queue_cap}, auth: {auth_desc}, \
                 store: {store_desc}, budget: {budget_desc}, schema {})",
                server.addr(),
                cfg.name,
                coordinator::API_SCHEMA,
            );
            // join() returns on graceful drain (POST /shutdown): every
            // in-flight request has finished, so flush the counters and
            // the store manifest before exiting
            server.join();
            write_counters(svc.as_ref(), "serve");
            finish_engine(svc.engine());
            eprintln!("pipefwd serve: drained and stopped");
        }
        "client" => {
            let action = positional
                .first()
                .map(String::as_str)
                .unwrap_or_else(|| {
                    fail("client <run|sweep|tune|stats|store-pull|store-push> \
                          (see `pipefwd` usage)")
                });
            // one persistent, retrying connection for the whole action:
            // transient failures (503 backpressure, admission sheds,
            // resets, truncated streams) back off and retry; permanent
            // errors still fail
            let mut cli = net::Client::new(&addr)
                .with_token(token.clone())
                .with_deadline(deadline_ms);
            match action {
                "run" => {
                    let exps = req("--experiment", service::experiments_from(&experiment));
                    let items = cli
                        .request(&ServiceRequest::Run {
                            experiments: exps.clone(),
                            scale,
                            shard,
                            device: device_flag.clone(),
                        })
                        .unwrap_or_else(|e| fail(&e));
                    // mirror the CLI shard rule: a slice writes a sink
                    // only to an explicit --out
                    if shard.is_none() || out_set {
                        let bench = service::cells_to_bench(&items, scale, &exps)
                            .unwrap_or_else(|e| fail(&e));
                        match std::fs::write(&out_path, &bench) {
                            Ok(()) => eprintln!("wrote {out_path} (measured by {addr})"),
                            Err(e) => fail(&format!("writing {out_path}: {e}")),
                        }
                    } else {
                        eprintln!(
                            "shard complete on {addr} ({} cell(s))",
                            items.len().saturating_sub(1)
                        );
                    }
                }
                "sweep" => {
                    let items = cli
                        .request(&ServiceRequest::Sweep {
                            benches: benches.clone(),
                            depths: depths.clone(),
                            scale,
                            device: device_flag.clone(),
                        })
                        .unwrap_or_else(|e| fail(&e));
                    let bench =
                        service::cells_to_bench(&items, scale, &[]).unwrap_or_else(|e| fail(&e));
                    match std::fs::write(&out_path, &bench) {
                        Ok(()) => eprintln!("wrote {out_path} (measured by {addr})"),
                        Err(e) => fail(&format!("writing {out_path}: {e}")),
                    }
                }
                "tune" => {
                    let items = cli
                        .request(&ServiceRequest::Tune {
                            benches: benches.clone(),
                            policy,
                            budget,
                            replication,
                            scale,
                            reference: !no_ref,
                            device: device_flag.clone(),
                        })
                        .unwrap_or_else(|e| fail(&e));
                    let report_doc = items
                        .first()
                        .and_then(|l| l.get("report"))
                        .cloned()
                        .unwrap_or_else(|| fail("client tune: malformed daemon response"));
                    let tune_path =
                        if out_set { out_path.clone() } else { "TUNE.json".to_string() };
                    match json::write_file_atomic(Path::new(&tune_path), &report_doc) {
                        Ok(()) => eprintln!("wrote {tune_path} (tuned by {addr})"),
                        Err(e) => fail(&format!("writing {tune_path}: {e}")),
                    }
                }
                "stats" => {
                    let doc = cli.get_stats().unwrap_or_else(|e| fail(&e));
                    print!("{}", doc.to_pretty());
                }
                "store-pull" => {
                    let items =
                        cli.request(&ServiceRequest::StorePull).unwrap_or_else(|e| fail(&e));
                    let records = items
                        .iter()
                        .map(service::decode_record)
                        .collect::<Result<Vec<_>, _>>()
                        .unwrap_or_else(|e| fail(&e));
                    let dir = Store::resolve_dir(cache_dir.as_deref());
                    let store = Store::open(&dir)
                        .unwrap_or_else(|e| fail(&format!("opening store {}: {e}", dir.display())));
                    let report = store
                        .import_records(&records)
                        .unwrap_or_else(|e| fail(&format!("importing records: {e}")));
                    if let Err(e) = store.write_manifest() {
                        eprintln!("warning: writing store manifest: {e}");
                    }
                    eprintln!(
                        "pulled {} record(s) from {addr}, imported {} new into {} \
                         ({} rejected)",
                        records.len(),
                        report.imported,
                        dir.display(),
                        report.rejected,
                    );
                }
                "store-push" => {
                    // upload this machine's store for server-side
                    // verification: the daemon re-hashes every pool
                    // file, re-validates every document, and admits
                    // through its own byte budget
                    let dir = Store::resolve_dir(cache_dir.as_deref());
                    let store = Store::open_existing(&dir).unwrap_or_else(|e| {
                        fail(&format!("opening store {}: {e}", dir.display()))
                    });
                    let records = store.export_records();
                    if records.is_empty() {
                        fail(&format!("store {} has no records to push", dir.display()));
                    }
                    let n = records.len();
                    let items = cli
                        .request(&ServiceRequest::StorePush { records })
                        .unwrap_or_else(|e| fail(&e));
                    let field = |k: &str| {
                        items
                            .first()
                            .and_then(|l| l.get(k))
                            .and_then(|v| v.as_u64())
                            .unwrap_or(0)
                    };
                    eprintln!(
                        "pushed {n} record(s) to {addr}: {} imported, {} rejected, \
                         {} claim(s) fulfilled",
                        field("count"),
                        field("rejected"),
                        field("fulfilled"),
                    );
                }
                other => {
                    fail(&format!(
                        "unknown client action `{other}` \
                         (run|sweep|tune|stats|store-pull|store-push)"
                    ))
                }
            }
            if cli.retries() > 0 {
                eprintln!(
                    "(recovered from transient failures: {} retr{})",
                    cli.retries(),
                    if cli.retries() == 1 { "y" } else { "ies" }
                );
            }
        }
        "report" => {
            if let Some((old_path, new_path)) = args.pair("--diff") {
                let (rendered, failures) =
                    pipefwd::report::sink_diff(old_path, new_path, threshold)
                        .unwrap_or_else(|e| fail(&e));
                print!("{rendered}");
                if failures > 0 {
                    eprintln!(
                        "FAIL: {failures} gate failure(s) — regressions above {threshold}% \
                         or configurations lost (old: {old_path}, new: {new_path})"
                    );
                    std::process::exit(1);
                }
                return;
            }
            match std::fs::read_to_string(&in_path) {
                Ok(text) => {
                    let doc = json::parse(&text)
                        .unwrap_or_else(|e| fail(&format!("parsing {in_path}: {e}")));
                    match format.as_str() {
                        "json" => print!("{}", doc.to_pretty()),
                        _ => {
                            let ms: Vec<coordinator::Measurement> = doc
                                .get("measurements")
                                .and_then(|m| m.as_array())
                                .unwrap_or_else(|| {
                                    fail(&format!("{in_path}: no measurements array"))
                                })
                                .iter()
                                .filter_map(coordinator::Measurement::from_json)
                                .collect();
                            let t = pipefwd::report::measurements_table(
                                &format!("Results sink: {in_path}"),
                                &ms,
                            );
                            print!("{}", t.to_markdown());
                        }
                    }
                }
                Err(read_err) => {
                    // the DEFAULT sink file is absent: render from the
                    // persistent store instead of erroring — restricted to
                    // the requested scale and estimator, since the store
                    // accumulates entries across both. An explicitly
                    // requested --in file, or any error other than
                    // not-found, still fails: silently substituting store
                    // data for a named file would hand scripts wrong data.
                    if in_set || read_err.kind() != std::io::ErrorKind::NotFound {
                        fail(&format!("reading {in_path}: {read_err}"));
                    }
                    // read-only path: open the store only if it already
                    // exists (no create_dir_all side effect)
                    let store = (!no_cache)
                        .then(|| {
                            Store::open_existing(Store::resolve_dir(cache_dir.as_deref())).ok()
                        })
                        .flatten()
                        .unwrap_or_else(|| {
                            fail(&format!(
                                "reading {in_path}: {read_err} (run `pipefwd run` first)"
                            ))
                        });
                    let ms =
                        store.measurements_filtered(coordinator::scale_label(scale), use_des);
                    if ms.is_empty() {
                        fail(&format!(
                            "reading {in_path}: {read_err} (and store {} has no {} {} \
                             measurements — run `pipefwd run` first)",
                            store.root().display(),
                            coordinator::scale_label(scale),
                            if use_des { "DES" } else { "analytic" },
                        ));
                    }
                    match format.as_str() {
                        "json" => print!("{}", coordinator::bench_doc(scale, &[], &ms)),
                        _ => {
                            let title = format!(
                                "Results sink: store {} ({}, {})",
                                store.root().display(),
                                coordinator::scale_label(scale),
                                if use_des { "des" } else { "analytic" },
                            );
                            print!(
                                "{}",
                                pipefwd::report::measurements_table(&title, &ms).to_markdown()
                            );
                        }
                    }
                }
            }
        }
        "store" => {
            let action = positional
                .first()
                .map(String::as_str)
                .unwrap_or_else(|| fail("store <stats|gc> (see `pipefwd` usage)"));
            // operate on the store in place: it must already exist —
            // fabricating an empty one just to stat or gc it would hide a
            // typo'd --cache-dir
            let dir = Store::resolve_dir(cache_dir.as_deref());
            let store = Store::open_existing(&dir)
                .unwrap_or_else(|e| fail(&format!("opening store {}: {e}", dir.display())));
            let svc =
                Service::cli(Engine::new(cfg.clone(), 1).with_des(use_des).with_store(store));
            match action {
                "stats" => {
                    let resp = svc
                        .handle(&ServiceRequest::StoreStats)
                        .unwrap_or_else(|e| fail(&e.render()));
                    let ServiceResponse::StoreStats { stats } = resp else {
                        fail("store stats: unexpected response kind")
                    };
                    match format.as_str() {
                        "json" => print!("{}", stats.to_json().to_pretty()),
                        _ => {
                            let schema = coordinator::store::STORE_SCHEMA;
                            let mut t = pipefwd::report::Table::new(
                                &format!("Store {} ({schema})", dir.display()),
                                &["Tier", "Records", "Bytes"],
                            );
                            for (name, tier) in [
                                ("entries", stats.entries),
                                ("traces", stats.traces),
                                ("profiles (pool)", stats.profiles),
                                ("journal (overhead)", stats.journal),
                            ] {
                                t.row(vec![
                                    name.into(),
                                    tier.count.to_string(),
                                    tier.bytes.to_string(),
                                ]);
                            }
                            print!("{}", t.to_markdown());
                            println!(
                                "\nprofile refs: {} across {} pooled profiles \
                                 (dedup ratio {:.2}x)",
                                stats.profile_refs,
                                stats.profiles.count,
                                stats.dedup_ratio(),
                            );
                            // journal/droppings overhead is bookkeeping,
                            // never charged against the byte budget
                            match stats.max_bytes.or(max_bytes) {
                                Some(max) => println!(
                                    "governed bytes: {} of {max} budget",
                                    stats.governed_bytes(),
                                ),
                                None => println!(
                                    "governed bytes: {} (no budget)",
                                    stats.governed_bytes(),
                                ),
                            }
                        }
                    }
                }
                "gc" => {
                    let resp = svc
                        .handle(&ServiceRequest::StoreGc { dry_run })
                        .unwrap_or_else(|e| fail(&e.render()));
                    let ServiceResponse::Gc { report } = resp else {
                        fail("store gc: unexpected response kind")
                    };
                    let verb = if dry_run { "would remove" } else { "removed" };
                    let removed_col = if dry_run { "Would remove" } else { "Removed" };
                    let mut t = pipefwd::report::Table::new(
                        &format!(
                            "Store gc {}{}",
                            dir.display(),
                            if dry_run { " (dry run)" } else { "" }
                        ),
                        &["Tier", "Kept", removed_col],
                    );
                    t.row(vec![
                        "entries".into(),
                        report.kept_entries.to_string(),
                        report.removed_entries.to_string(),
                    ]);
                    t.row(vec![
                        "traces".into(),
                        report.kept_traces.to_string(),
                        report.removed_traces.to_string(),
                    ]);
                    t.row(vec![
                        "profiles (pool)".into(),
                        report.kept_profiles.to_string(),
                        report.removed_profiles.to_string(),
                    ]);
                    print!("{}", t.to_markdown());
                    eprintln!(
                        "{verb} {} unreachable record(s){}",
                        report.removed_total(),
                        if dry_run { "" } else { "; MANIFEST.json rewritten" },
                    );
                }
                other => fail(&format!("unknown store action `{other}` (stats|gc)")),
            }
        }
        "table1" => save(&coordinator::table1(scale), "table1"),
        "table2" => save(&coordinator::table2(scale, &cfg), "table2"),
        "figure4" => save(&coordinator::figure4(scale, &cfg), "figure4"),
        "table3" => save(&coordinator::table3(scale, &cfg), "table3"),
        "intext" => save(&coordinator::intext(scale, &cfg), "intext"),
        "sweeps" => {
            let mut engine = Engine::new(cfg, jobs);
            if tuned {
                engine = engine.with_tuner(coordinator::TuneSpec { policy, budget });
            }
            let trio = ["fw", "hotspot", "mis"];
            save(&engine.depth_sweep(&trio, scale, &[1, 100, 1000]), "depth_sweep");
            save(&engine.pc_sweep(&trio, scale), "pc_sweep");
        }
        "vectors" => save(&coordinator::vector_study(scale, &cfg), "vector_study"),
        "micro-family" => save(&coordinator::micro_family(scale, &cfg), "micro_family"),
        "headline" => {
            let h = coordinator::headline(scale, &cfg);
            println!(
                "max feed-forward speedup : {:.1}x   (paper: up to 65x)",
                h.max_ff_speedup
            );
            println!(
                "avg speedup (gainers)    : {:.1}x   (paper: ~20x average)",
                h.avg_ff_speedup_gainers
            );
            println!(
                "max with M2C2            : {:.1}x   (paper: up to 86x)",
                h.max_total_speedup
            );
        }
        "all" => {
            for t in coordinator::full_evaluation(scale, &cfg, csv) {
                print!("{}", t.to_markdown());
                println!();
            }
        }
        "report-kernel" => {
            let name = positional.first().unwrap_or_else(|| fail("report-kernel <bench>"));
            let w = by_name(name).unwrap_or_else(|| fail(&format!("unknown benchmark `{name}`")));
            for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
                match w.build(variant) {
                    Ok(app) => {
                        let union = app.union_program();
                        let rep = pipefwd::analysis::program_report(&union, &cfg);
                        println!("--- {} ---", variant.label());
                        print!("{}", rep.render());
                    }
                    Err(e) => println!("--- {} --- infeasible: {e}", variant.label()),
                }
            }
        }
        "source" => {
            let name = positional.first().unwrap_or_else(|| fail("source <bench>"));
            let w = by_name(name).unwrap_or_else(|| fail(&format!("unknown benchmark `{name}`")));
            for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
                match w.build(variant) {
                    Ok(app) => {
                        println!("// ===== {} =====", variant.label());
                        for u in &app.units {
                            print!("{}", pipefwd::ir::pretty::program_to_string(u));
                            println!();
                        }
                    }
                    Err(e) => println!("// ===== {} ===== infeasible: {e}", variant.label()),
                }
            }
        }
        "golden" => {
            let rt = pipefwd::runtime::Runtime::open_default().unwrap_or_else(|e| {
                eprintln!("cannot open artifacts: {e:#}");
                std::process::exit(1);
            });
            match pipefwd::runtime::golden::check_all(&rt) {
                Ok(results) => {
                    for (name, d) in results {
                        println!("{name:>18}: max |diff| vs PJRT golden = {d:.2e}  OK");
                    }
                }
                Err(e) => {
                    eprintln!("golden validation FAILED: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}
