//! `pipefwd` CLI — the leader entrypoint.
//!
//! Subcommands regenerate the paper's tables/figures, print compiler
//! reports and transformed source, validate against the PJRT golden
//! artifacts, and drive the parallel experiment engine (`run`, `sweep`,
//! `report`). Std-only argument parsing (no clap in this offline image).

use pipefwd::coordinator::{self, parse_scale, Engine, ExperimentId};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::{by_name, Scale};

const USAGE: &str = "\
pipefwd — feed-forward design model for OpenCL kernels via pipes
          (simulated-FPGA reproduction; see DESIGN.md)

USAGE: pipefwd <command> [--scale tiny|small|paper] [--csv] [--jobs N]

ENGINE COMMANDS (parallel, cache-aware):
  run --experiment E1..E7|all   run experiments through the engine and
                                write the BENCH_PR1.json results sink
  sweep [--depths 1,100,1000]   channel-depth sweep over arbitrary depths
        [--benches fw,hotspot,mis]
  report [--format table|json]  re-render a results sink (default:
         [--in BENCH_PR1.json]  BENCH_PR1.json) as a table or as JSON

TABLE COMMANDS:
  table1               benchmark characterisation (paper Table 1)
  table2               feed-forward vs baseline (paper Table 2)
  figure4              M2C2 speedup + overhead (paper Figure 4)
  table3               microbenchmarks (paper Table 3)
  intext               II / bandwidth numbers quoted in the text (E4a/b)
  sweeps               channel-depth + producer/consumer sweeps (E4c/d)
  vectors              vector-type case study (E4e)
  micro-family         extended microbenchmark family (future work)
  headline             the paper's headline speedup claims (E7)
  all                  everything above, in order
  report-kernel <b>    early-stage compiler report, baseline vs FF (E4a)
  source <bench>       OpenCL-flavoured source, baseline and FF kernels
  golden               validate IR numerics against PJRT artifacts
  list                 list benchmarks

OPTIONS:
  --scale S        dataset scale (default: small; tiny = artifact-matched)
  --csv            also write results/<name>.csv
  --jobs N         engine worker threads (default: all cores)
  --out PATH       results-sink path for `run`/`sweep` (default: BENCH_PR1.json)
  --experiment E   comma-separated experiment ids for `run` (E1..E7 or all)
  --depths LIST    comma-separated pipe depths for `sweep`
  --benches LIST   comma-separated benchmarks for `sweep`
  --format F       `report` output: table (default) or json
  --in PATH        `report` input file (default: BENCH_PR1.json)
";

fn fail(msg: &str) -> ! {
    eprintln!("{msg}");
    std::process::exit(2);
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let mut scale = Scale::Small;
    let mut csv = false;
    let mut jobs: usize = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut experiment = String::from("all");
    let mut depths: Vec<usize> = vec![1, 100, 1000];
    let mut benches: Vec<String> = vec!["fw".into(), "hotspot".into(), "mis".into()];
    let mut out_path = String::from("BENCH_PR1.json");
    let mut in_path = String::from("BENCH_PR1.json");
    let mut format = String::from("table");
    let mut positional = vec![];
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--scale" => {
                let v = it.next().unwrap_or_else(|| fail("--scale needs a value"));
                scale = parse_scale(v)
                    .unwrap_or_else(|| fail(&format!("unknown scale `{v}` (tiny|small|paper)")));
            }
            "--csv" => csv = true,
            "--jobs" => {
                let v = it.next().unwrap_or_else(|| fail("--jobs needs a value"));
                jobs = v
                    .parse::<usize>()
                    .ok()
                    .filter(|n| *n > 0)
                    .unwrap_or_else(|| fail(&format!("bad --jobs `{v}` (positive integer)")));
            }
            "--experiment" => {
                experiment = it.next().unwrap_or_else(|| fail("--experiment needs a value")).clone();
            }
            "--depths" => {
                let v = it.next().unwrap_or_else(|| fail("--depths needs a value"));
                depths = v
                    .split(',')
                    .map(|d| {
                        d.trim()
                            .parse::<usize>()
                            .ok()
                            .filter(|n| *n > 0)
                            .unwrap_or_else(|| fail(&format!("bad depth `{d}`")))
                    })
                    .collect();
            }
            "--benches" => {
                let v = it.next().unwrap_or_else(|| fail("--benches needs a value"));
                benches = v.split(',').map(|b| b.trim().to_string()).collect();
            }
            "--out" => {
                out_path = it.next().unwrap_or_else(|| fail("--out needs a value")).clone();
            }
            "--in" => {
                in_path = it.next().unwrap_or_else(|| fail("--in needs a value")).clone();
            }
            "--format" => {
                format = it.next().unwrap_or_else(|| fail("--format needs a value")).clone();
            }
            other => positional.push(other.to_string()),
        }
    }
    let cfg = DeviceConfig::pac_a10();

    let save = |t: &pipefwd::report::Table, name: &str| {
        print!("{}", t.to_markdown());
        if csv {
            match t.save_csv(name) {
                Ok(p) => eprintln!("wrote {}", p.display()),
                Err(e) => eprintln!("csv write failed: {e}"),
            }
        }
    };

    match cmd {
        "list" => {
            for w in pipefwd::workloads::suite() {
                println!("{:>10}  {:8}  {}", w.name(), w.suite(), w.dataset_desc(scale));
            }
        }
        "run" => {
            let exps: Vec<ExperimentId> = if experiment.eq_ignore_ascii_case("all") {
                ExperimentId::all().to_vec()
            } else {
                experiment
                    .split(',')
                    .map(|e| {
                        ExperimentId::parse(e.trim())
                            .unwrap_or_else(|| fail(&format!("unknown experiment `{e}` (E1..E7)")))
                    })
                    .collect()
            };
            let engine = Engine::new(cfg, jobs);
            for exp in &exps {
                for (i, t) in engine.run_experiment(*exp, scale).iter().enumerate() {
                    save(t, &format!("{}_{i}", exp.label().to_lowercase()));
                    println!();
                }
            }
            match engine.write_bench_json(std::path::Path::new(&out_path), scale, &exps) {
                Ok(()) => eprintln!(
                    "wrote {out_path} ({} measurements, {} unique configs, {} cache hits, {jobs} jobs)",
                    engine.measurements().len(),
                    engine.cache_len(),
                    engine.cache_hits(),
                ),
                Err(e) => fail(&format!("writing {out_path}: {e}")),
            }
        }
        "sweep" => {
            for b in &benches {
                if coordinator::resolve_workload(b).is_none() {
                    fail(&format!("unknown benchmark `{b}` (see `pipefwd list`)"));
                }
            }
            let engine = Engine::new(cfg, jobs);
            let cells: Vec<coordinator::Cell> = benches
                .iter()
                .flat_map(|b| {
                    depths
                        .iter()
                        .map(|d| coordinator::Cell::new(b, Variant::FeedForward { depth: *d }, scale))
                        .collect::<Vec<_>>()
                })
                .collect();
            let _ = engine.run_cells(&cells);
            let names: Vec<&str> = benches.iter().map(|b| b.as_str()).collect();
            save(&engine.depth_sweep(&names, scale, &depths), "depth_sweep");
            match engine.write_bench_json(std::path::Path::new(&out_path), scale, &[]) {
                Ok(()) => eprintln!("wrote {out_path}"),
                Err(e) => fail(&format!("writing {out_path}: {e}")),
            }
        }
        "report" => {
            let text = std::fs::read_to_string(&in_path)
                .unwrap_or_else(|e| fail(&format!("reading {in_path}: {e} (run `pipefwd run` first)")));
            let doc = pipefwd::util::json::parse(&text)
                .unwrap_or_else(|e| fail(&format!("parsing {in_path}: {e}")));
            match format.as_str() {
                "json" => print!("{}", doc.to_pretty()),
                "table" => {
                    let ms: Vec<coordinator::Measurement> = doc
                        .get("measurements")
                        .and_then(|m| m.as_array())
                        .unwrap_or_else(|| fail(&format!("{in_path}: no measurements array")))
                        .iter()
                        .filter_map(coordinator::Measurement::from_json)
                        .collect();
                    let mut t = pipefwd::report::Table::new(
                        &format!("Results sink: {in_path}"),
                        &[
                            "Benchmark", "Variant", "Scale", "Time (ms)", "Logic (%)", "BRAM",
                            "Max II", "Max BW (MB/s)", "Launches",
                        ],
                    );
                    for m in &ms {
                        t.row(vec![
                            m.workload.clone(),
                            m.variant.clone(),
                            m.scale.clone(),
                            pipefwd::report::ms(m.seconds),
                            format!("{:.2}", m.logic_pct),
                            m.brams.to_string(),
                            m.max_ii.to_string(),
                            pipefwd::report::mbps(m.max_bw),
                            m.launches.to_string(),
                        ]);
                    }
                    print!("{}", t.to_markdown());
                }
                other => fail(&format!("unknown --format `{other}` (table|json)")),
            }
        }
        "table1" => save(&coordinator::table1(scale), "table1"),
        "table2" => save(&coordinator::table2(scale, &cfg), "table2"),
        "figure4" => save(&coordinator::figure4(scale, &cfg), "figure4"),
        "table3" => save(&coordinator::table3(scale, &cfg), "table3"),
        "intext" => save(&coordinator::intext(scale, &cfg), "intext"),
        "sweeps" => {
            let engine = Engine::new(cfg, jobs);
            let trio = ["fw", "hotspot", "mis"];
            save(&engine.depth_sweep(&trio, scale, &[1, 100, 1000]), "depth_sweep");
            save(&engine.pc_sweep(&trio, scale), "pc_sweep");
        }
        "vectors" => save(&coordinator::vector_study(scale, &cfg), "vector_study"),
        "micro-family" => save(&coordinator::micro_family(scale, &cfg), "micro_family"),
        "headline" => {
            let h = coordinator::headline(scale, &cfg);
            println!(
                "max feed-forward speedup : {:.1}x   (paper: up to 65x)",
                h.max_ff_speedup
            );
            println!(
                "avg speedup (gainers)    : {:.1}x   (paper: ~20x average)",
                h.avg_ff_speedup_gainers
            );
            println!(
                "max with M2C2            : {:.1}x   (paper: up to 86x)",
                h.max_total_speedup
            );
        }
        "all" => {
            for t in coordinator::full_evaluation(scale, &cfg, csv) {
                print!("{}", t.to_markdown());
                println!();
            }
        }
        "report-kernel" => {
            let name = positional.first().unwrap_or_else(|| fail("report-kernel <bench>"));
            let w = by_name(name).unwrap_or_else(|| fail(&format!("unknown benchmark `{name}`")));
            for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
                match w.build(variant) {
                    Ok(app) => {
                        let union = app.union_program();
                        let rep = pipefwd::analysis::program_report(&union, &cfg);
                        println!("--- {} ---", variant.label());
                        print!("{}", rep.render());
                    }
                    Err(e) => println!("--- {} --- infeasible: {e}", variant.label()),
                }
            }
        }
        "source" => {
            let name = positional.first().unwrap_or_else(|| fail("source <bench>"));
            let w = by_name(name).unwrap_or_else(|| fail(&format!("unknown benchmark `{name}`")));
            for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
                match w.build(variant) {
                    Ok(app) => {
                        println!("// ===== {} =====", variant.label());
                        for u in &app.units {
                            print!("{}", pipefwd::ir::pretty::program_to_string(u));
                            println!();
                        }
                    }
                    Err(e) => println!("// ===== {} ===== infeasible: {e}", variant.label()),
                }
            }
        }
        "golden" => {
            let rt = pipefwd::runtime::Runtime::open_default().unwrap_or_else(|e| {
                eprintln!("cannot open artifacts: {e:#}");
                std::process::exit(1);
            });
            match pipefwd::runtime::golden::check_all(&rt) {
                Ok(results) => {
                    for (name, d) in results {
                        println!("{name:>18}: max |diff| vs PJRT golden = {d:.2e}  OK");
                    }
                }
                Err(e) => {
                    eprintln!("golden validation FAILED: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}
