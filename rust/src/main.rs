//! `pipefwd` CLI — the leader entrypoint.
//!
//! Subcommands regenerate the paper's tables/figures, print compiler
//! reports and transformed source, and validate against the PJRT golden
//! artifacts. Std-only argument parsing (no clap in this offline image).

use pipefwd::coordinator::{self, parse_scale};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::{by_name, Scale};

const USAGE: &str = "\
pipefwd — feed-forward design model for OpenCL kernels via pipes
          (simulated-FPGA reproduction; see DESIGN.md)

USAGE: pipefwd <command> [--scale tiny|small|paper] [--csv]

COMMANDS:
  table1               benchmark characterisation (paper Table 1)
  table2               feed-forward vs baseline (paper Table 2)
  figure4              M2C2 speedup + overhead (paper Figure 4)
  table3               microbenchmarks (paper Table 3)
  intext               II / bandwidth numbers quoted in the text (E4a/b)
  sweeps               channel-depth + producer/consumer sweeps (E4c/d)
  vectors              vector-type case study (E4e)
  micro-family         extended microbenchmark family (future work)
  headline             the paper's headline speedup claims (E7)
  all                  everything above, in order
  report <bench>       early-stage compiler report, baseline vs FF (E4a)
  source <bench>       OpenCL-flavoured source, baseline and FF kernels
  golden               validate IR numerics against PJRT artifacts
  list                 list benchmarks

OPTIONS:
  --scale S   dataset scale (default: small; tiny = artifact-matched)
  --csv       also write results/<name>.csv
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let mut scale = Scale::Small;
    let mut csv = false;
    let mut positional = vec![];
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--scale" => {
                let v = it.next().expect("--scale needs a value");
                scale = parse_scale(v).unwrap_or_else(|| {
                    eprintln!("unknown scale `{v}` (tiny|small|paper)");
                    std::process::exit(2);
                });
            }
            "--csv" => csv = true,
            other => positional.push(other.to_string()),
        }
    }
    let cfg = DeviceConfig::pac_a10();

    let save = |t: &pipefwd::report::Table, name: &str| {
        print!("{}", t.to_markdown());
        if csv {
            match t.save_csv(name) {
                Ok(p) => eprintln!("wrote {}", p.display()),
                Err(e) => eprintln!("csv write failed: {e}"),
            }
        }
    };

    match cmd {
        "list" => {
            for w in pipefwd::workloads::suite() {
                println!("{:>10}  {:8}  {}", w.name(), w.suite(), w.dataset_desc(scale));
            }
        }
        "table1" => save(&coordinator::table1(scale), "table1"),
        "table2" => save(&coordinator::table2(scale, &cfg), "table2"),
        "figure4" => save(&coordinator::figure4(scale, &cfg), "figure4"),
        "table3" => save(&coordinator::table3(scale, &cfg), "table3"),
        "intext" => save(&coordinator::intext(scale, &cfg), "intext"),
        "sweeps" => {
            save(&coordinator::depth_sweep(&["fw", "hotspot", "mis"], scale, &cfg), "depth_sweep");
            save(&coordinator::pc_sweep(&["fw", "hotspot", "mis"], scale, &cfg), "pc_sweep");
        }
        "vectors" => save(&coordinator::vector_study(scale, &cfg), "vector_study"),
        "micro-family" => save(&coordinator::micro_family(scale, &cfg), "micro_family"),
        "headline" => {
            let h = coordinator::headline(scale, &cfg);
            println!(
                "max feed-forward speedup : {:.1}x   (paper: up to 65x)",
                h.max_ff_speedup
            );
            println!(
                "avg speedup (gainers)    : {:.1}x   (paper: ~20x average)",
                h.avg_ff_speedup_gainers
            );
            println!(
                "max with M2C2            : {:.1}x   (paper: up to 86x)",
                h.max_total_speedup
            );
        }
        "all" => {
            for t in coordinator::full_evaluation(scale, &cfg, csv) {
                print!("{}", t.to_markdown());
                println!();
            }
        }
        "report" => {
            let name = positional.first().expect("report <bench>");
            let w = by_name(name).unwrap_or_else(|| {
                eprintln!("unknown benchmark `{name}`");
                std::process::exit(2);
            });
            for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
                match w.build(variant) {
                    Ok(app) => {
                        let union = app.union_program();
                        let rep = pipefwd::analysis::program_report(&union, &cfg);
                        println!("--- {} ---", variant.label());
                        print!("{}", rep.render());
                    }
                    Err(e) => println!("--- {} --- infeasible: {e}", variant.label()),
                }
            }
        }
        "source" => {
            let name = positional.first().expect("source <bench>");
            let w = by_name(name).unwrap_or_else(|| {
                eprintln!("unknown benchmark `{name}`");
                std::process::exit(2);
            });
            for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
                match w.build(variant) {
                    Ok(app) => {
                        println!("// ===== {} =====", variant.label());
                        for u in &app.units {
                            print!("{}", pipefwd::ir::pretty::program_to_string(u));
                            println!();
                        }
                    }
                    Err(e) => println!("// ===== {} ===== infeasible: {e}", variant.label()),
                }
            }
        }
        "golden" => {
            let rt = pipefwd::runtime::Runtime::open_default().unwrap_or_else(|e| {
                eprintln!("cannot open artifacts: {e:#}");
                std::process::exit(1);
            });
            match pipefwd::runtime::golden::check_all(&rt) {
                Ok(results) => {
                    for (name, d) in results {
                        println!("{name:>18}: max |diff| vs PJRT golden = {d:.2e}  OK");
                    }
                }
                Err(e) => {
                    eprintln!("golden validation FAILED: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}
