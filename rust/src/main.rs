//! `pipefwd` CLI — the leader entrypoint.
//!
//! Subcommands regenerate the paper's tables/figures, print compiler
//! reports and transformed source, validate against the PJRT golden
//! artifacts, and drive the parallel experiment engine (`run`, `sweep`,
//! `report`). Std-only argument parsing (no clap in this offline image).

use pipefwd::coordinator::{self, parse_scale, Engine, ExperimentId, Store};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::{by_name, Scale};

const USAGE: &str = "\
pipefwd — feed-forward design model for OpenCL kernels via pipes
          (simulated-FPGA reproduction; see DESIGN.md)

USAGE: pipefwd <command> [--scale tiny|small|paper] [--csv] [--jobs N]

ENGINE COMMANDS (parallel, cache-aware, persistent):
  run --experiment E1..E7|all   run experiments through the engine and
      [--shard I/N] [--des]     write the BENCH_PR1.json results sink;
                                --shard computes one disjoint grid slice
  sweep [--depths 1,100,1000]   channel-depth sweep over arbitrary depths
        [--benches fw,hotspot,mis]
  tune --benches LIST           autotune (pipe depth x replication) per
       [--policy golden|sh]     workload with a budgeted search instead
       [--budget 40]            of an exhaustive grid; renders a
       [--replication]          TuneReport table and writes TUNE.json
       [--no-ref]               (--out overrides the path)
  merge <dir>...                union shard stores and emit the canonical
                                BENCH_PR1.json (byte-identical to serial)
  report [--format table|json]  re-render a results sink (default:
         [--in BENCH_PR1.json]  BENCH_PR1.json; if the default file is
                                absent, renders from the persistent store)
  report --diff <old> <new>     compare two results sinks; exit 1 on
         [--threshold PCT]      modelled-performance regressions > PCT %
  store stats                   per-tier store footprint (entries /
        [--format table|json]   traces / pooled profiles, counts + bytes)
                                and the profile pool's dedup ratio
  store gc [--dry-run]          delete every store record unreachable
                                from the current E1-E7 grids (all scales,
                                both estimators) and the tuner's
                                depth x replication ladders, plus pooled
                                profiles no surviving trace references;
                                rewrites MANIFEST.json (--dry-run only
                                reports)

TABLE COMMANDS:
  table1               benchmark characterisation (paper Table 1)
  table2               feed-forward vs baseline (paper Table 2)
  figure4              M2C2 speedup + overhead (paper Figure 4)
  table3               microbenchmarks (paper Table 3)
  intext               II / bandwidth numbers quoted in the text (E4a/b)
  sweeps               channel-depth + producer/consumer sweeps (E4c/d)
  vectors              vector-type case study (E4e)
  micro-family         extended microbenchmark family (future work)
  headline             the paper's headline speedup claims (E7)
  all                  everything above, in order
  report-kernel <b>    early-stage compiler report, baseline vs FF (E4a)
  source <bench>       OpenCL-flavoured source, baseline and FF kernels
  golden               validate IR numerics against PJRT artifacts
  list                 list benchmarks

OPTIONS:
  --scale S        dataset scale (default: small; tiny = artifact-matched)
  --csv            also write results/<name>.csv
  --jobs N         engine worker threads (default: all cores)
  --out PATH       results-sink path for `run`/`sweep`/`merge`
                   (default: BENCH_PR1.json)
  --experiment E   comma-separated experiment ids (E1..E7 or all)
  --depths LIST    comma-separated pipe depths for `sweep` (sorted and
                   deduplicated; duplicate columns would break the
                   deterministic-output guarantees)
  --benches LIST   comma-separated benchmarks for `sweep`/`tune`
                   (validated against the workload registry at parse time)
  --policy P       search policy for `tune`/`--tuned`: golden
                   (golden-section over log-depth) or sh (successive
                   halving over depth x replication, cheap scales first)
  --budget N       max distinct probes a search may spend (default 40) —
                   on a cold store, the max simulations
  --replication    include replication factors m2c2..m4c4 in the tuned
                   configuration space
  --no-ref         skip the TuneReport's exhaustive-reference column
                   (the regret baseline costs the full grid once)
  --dry-run        `store gc`: report what would be deleted without
                   touching the store (not even the manifest)
  --tuned          `run`/`sweep`: let the tuner pick best-ff depths for
                   the E1/E2/E7 tables and annotate the E4 depth sweep
  --format F       `report` output: table (default) or json
  --in PATH        `report` input file (default: BENCH_PR1.json)
  --diff OLD NEW   `report` diff mode: two results sinks to compare
  --threshold PCT  regression threshold for `report --diff` (default: 5)
  --shard I/N      compute only shard I of N (1-based) of the unique
                   experiment grid; merge the stores afterwards
  --cache-dir DIR  persistent measurement store directory
                   (default: $PIPEFWD_CACHE_DIR or .pipefwd-cache)
  --no-cache       do not read or write the persistent store
  --des            estimate with the discrete-event simulator instead of
                   the analytic model (cached under a distinct key)
  --counters PATH  after `run`/`sweep`/`tune`, write the engine counters
                   (trace_hits/trace_runs/store_hits/simulations/
                   cache_hits) plus wall-clock to a COUNTERS.json document
                   — CI gates on a warm rerun reporting zero trace runs
";

fn fail(msg: &str) -> ! {
    eprintln!("{msg}");
    std::process::exit(2);
}

fn main() {
    let wall_start = std::time::Instant::now();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    let mut scale = Scale::Small;
    let mut csv = false;
    let mut jobs: usize = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut experiment = String::from("all");
    let mut depths: Vec<usize> = vec![1, 100, 1000];
    let mut benches: Vec<String> = vec!["fw".into(), "hotspot".into(), "mis".into()];
    let mut out_path = String::from("BENCH_PR1.json");
    let mut out_set = false;
    let mut in_path = String::from("BENCH_PR1.json");
    let mut in_set = false;
    let mut format = String::from("table");
    let mut shard: Option<(usize, usize)> = None;
    let mut cache_dir: Option<String> = None;
    let mut no_cache = false;
    let mut use_des = false;
    let mut counters_path: Option<String> = None;
    let mut policy = coordinator::Policy::Golden;
    let mut budget: usize = 40;
    let mut replication = false;
    let mut dry_run = false;
    let mut no_ref = false;
    let mut tuned = false;
    let mut diff: Option<(String, String)> = None;
    let mut threshold = 5.0_f64;
    let mut positional = vec![];
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--scale" => {
                let v = it.next().unwrap_or_else(|| fail("--scale needs a value"));
                scale = parse_scale(v)
                    .unwrap_or_else(|| fail(&format!("unknown scale `{v}` (tiny|small|paper)")));
            }
            "--csv" => csv = true,
            "--jobs" => {
                let v = it.next().unwrap_or_else(|| fail("--jobs needs a value"));
                jobs = v
                    .parse::<usize>()
                    .ok()
                    .filter(|n| *n > 0)
                    .unwrap_or_else(|| fail(&format!("bad --jobs `{v}` (positive integer)")));
            }
            "--experiment" => {
                experiment = it.next().unwrap_or_else(|| fail("--experiment needs a value")).clone();
            }
            "--depths" => {
                let v = it.next().unwrap_or_else(|| fail("--depths needs a value"));
                // sorted + deduplicated: `--depths 100,100,1` must emit
                // the same table (and sink) as `--depths 1,100`
                depths = coordinator::normalize_depths(
                    v.split(',')
                        .map(|d| {
                            d.trim()
                                .parse::<usize>()
                                .ok()
                                .filter(|n| *n > 0)
                                .unwrap_or_else(|| fail(&format!("bad depth `{d}`")))
                        })
                        .collect(),
                );
            }
            "--benches" => {
                let v = it.next().unwrap_or_else(|| fail("--benches needs a value"));
                benches = v.split(',').map(|b| b.trim().to_string()).collect();
                // fail fast at parse time — an unknown name must not flow
                // into the engine's grid fan-out
                for b in &benches {
                    if coordinator::resolve_workload(b).is_none() {
                        fail(&format!("unknown benchmark `{b}` (see `pipefwd list`)"));
                    }
                }
            }
            "--policy" => {
                let v = it.next().unwrap_or_else(|| fail("--policy needs a value"));
                policy = coordinator::Policy::parse(v)
                    .unwrap_or_else(|| fail(&format!("unknown policy `{v}` (golden|sh)")));
            }
            "--budget" => {
                let v = it.next().unwrap_or_else(|| fail("--budget needs a value"));
                budget = v
                    .parse::<usize>()
                    .ok()
                    .filter(|n| *n > 0)
                    .unwrap_or_else(|| fail(&format!("bad --budget `{v}` (positive integer)")));
            }
            "--replication" => replication = true,
            "--dry-run" => dry_run = true,
            "--no-ref" => no_ref = true,
            "--tuned" => tuned = true,
            "--out" => {
                out_path = it.next().unwrap_or_else(|| fail("--out needs a value")).clone();
                out_set = true;
            }
            "--in" => {
                in_path = it.next().unwrap_or_else(|| fail("--in needs a value")).clone();
                in_set = true;
            }
            "--format" => {
                format = it.next().unwrap_or_else(|| fail("--format needs a value")).clone();
            }
            "--shard" => {
                let v = it.next().unwrap_or_else(|| fail("--shard needs a value (I/N)"));
                shard = Some(parse_shard(v).unwrap_or_else(|| {
                    fail(&format!("bad --shard `{v}` (expected I/N with 1 <= I <= N)"))
                }));
            }
            "--cache-dir" => {
                cache_dir =
                    Some(it.next().unwrap_or_else(|| fail("--cache-dir needs a value")).clone());
            }
            "--no-cache" => no_cache = true,
            "--des" => use_des = true,
            "--counters" => {
                counters_path =
                    Some(it.next().unwrap_or_else(|| fail("--counters needs a path")).clone());
            }
            "--diff" => {
                let old = it.next().unwrap_or_else(|| fail("--diff needs two paths")).clone();
                let new = it.next().unwrap_or_else(|| fail("--diff needs two paths")).clone();
                diff = Some((old, new));
            }
            "--threshold" => {
                let v = it.next().unwrap_or_else(|| fail("--threshold needs a value"));
                threshold = v
                    .parse::<f64>()
                    .ok()
                    .filter(|t| t.is_finite() && *t >= 0.0)
                    .unwrap_or_else(|| fail(&format!("bad --threshold `{v}` (percent >= 0)")));
            }
            other => positional.push(other.to_string()),
        }
    }
    let cfg = DeviceConfig::pac_a10();

    // The persistent store every engine command reads through / writes
    // behind (tentpole of PR 2); `--no-cache` restores PR-1 behavior.
    let open_store = || -> Option<Store> {
        if no_cache {
            return None;
        }
        let dir = Store::resolve_dir(cache_dir.as_deref());
        match Store::open(&dir) {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("warning: cannot open store {}: {e} (running uncached)", dir.display());
                None
            }
        }
    };
    let mk_engine = |jobs: usize| {
        let mut e = Engine::new(DeviceConfig::pac_a10(), jobs).with_des(use_des);
        if let Some(s) = open_store() {
            e = e.with_store(s);
        }
        if tuned {
            e = e.with_tuner(coordinator::TuneSpec { policy, budget });
        }
        e
    };
    // `--counters PATH`: the engine's tier counters + wall clock as one
    // machine-readable document per invocation. CI's warm-rerun gate reads
    // `trace_runs`/`simulations` from here (bench-diff fails on nonzero).
    let write_counters = |engine: &Engine, command: &str| {
        let Some(path) = counters_path.as_deref() else { return };
        let doc = pipefwd::util::json::Json::Obj(vec![
            ("schema".into(), pipefwd::util::json::Json::Str("pipefwd-counters-v1".into())),
            ("command".into(), pipefwd::util::json::Json::Str(command.into())),
            (
                "scale".into(),
                pipefwd::util::json::Json::Str(coordinator::scale_label(scale).into()),
            ),
            ("cache_hits".into(), pipefwd::util::json::Json::Num(engine.cache_hits() as f64)),
            ("store_hits".into(), pipefwd::util::json::Json::Num(engine.store_hits() as f64)),
            ("simulations".into(), pipefwd::util::json::Json::Num(engine.simulations() as f64)),
            ("trace_hits".into(), pipefwd::util::json::Json::Num(engine.trace_hits() as f64)),
            ("trace_runs".into(), pipefwd::util::json::Json::Num(engine.trace_runs() as f64)),
            (
                "wall_ms".into(),
                pipefwd::util::json::Json::Num(wall_start.elapsed().as_millis() as f64),
            ),
        ]);
        match pipefwd::util::json::write_file_atomic(std::path::Path::new(path), &doc) {
            Ok(()) => eprintln!("wrote {path}"),
            Err(e) => fail(&format!("writing {path}: {e}")),
        }
    };
    let finish_engine = |engine: &Engine| {
        if let Some(s) = engine.store() {
            if let Err(e) = s.write_manifest() {
                eprintln!("warning: writing store manifest: {e}");
            }
        }
    };

    let save = |t: &pipefwd::report::Table, name: &str| {
        print!("{}", t.to_markdown());
        if csv {
            match t.save_csv(name) {
                Ok(p) => eprintln!("wrote {}", p.display()),
                Err(e) => eprintln!("csv write failed: {e}"),
            }
        }
    };

    match cmd {
        "list" => {
            for w in pipefwd::workloads::suite() {
                println!("{:>10}  {:8}  {}", w.name(), w.suite(), w.dataset_desc(scale));
            }
        }
        "run" => {
            let exps = parse_experiments(&experiment);
            let engine = mk_engine(jobs);
            if let Some((index, count)) = shard {
                // one disjoint slice of the unique grid: simulate into the
                // store, no table rendering (tables need the full grid —
                // that's what `merge` reassembles). The store IS the
                // shard's product, so store problems are fatal here where
                // a plain run only warns.
                if engine.store().is_none() {
                    fail("run --shard: the persistent store is unavailable (or --no-cache \
                          was given) — a shard's results have nowhere to go");
                }
                let cells = coordinator::grid_for(&exps, scale);
                let slice = coordinator::shard_cells(&cells, index, count)
                    .unwrap_or_else(|e| fail(&e));
                let _ = engine.run_cells(&slice);
                if engine.store_errors() > 0 {
                    fail(&format!(
                        "run --shard: {} result(s) failed to persist — the merge would \
                         report this slice as missing",
                        engine.store_errors()
                    ));
                }
                eprintln!(
                    "shard {index}/{count}: {} of {} unique cells, {} simulated \
                     ({} trace runs, {} trace hits), {} store hits",
                    slice.len(),
                    cells.len(),
                    engine.simulations(),
                    engine.trace_runs(),
                    engine.trace_hits(),
                    engine.store_hits(),
                );
            } else {
                for exp in &exps {
                    for (i, t) in engine.run_experiment(*exp, scale).iter().enumerate() {
                        save(t, &format!("{}_{i}", exp.label().to_lowercase()));
                        println!();
                    }
                }
            }
            // A shard's product is its store entries; a partial sink under
            // the default name would masquerade as a complete one (and
            // concurrent shards would race on it), so shards only write a
            // sink to an explicit --out.
            if shard.is_none() || out_set {
                match engine.write_bench_json(std::path::Path::new(&out_path), scale, &exps) {
                    Ok(()) => eprintln!(
                        "wrote {out_path} ({} measurements, {} unique configs, {} cache hits, \
                         {} store hits, {} simulated, {} trace runs, {} trace hits, {jobs} jobs)",
                        engine.measurements().len(),
                        engine.cache_len(),
                        engine.cache_hits(),
                        engine.store_hits(),
                        engine.simulations(),
                        engine.trace_runs(),
                        engine.trace_hits(),
                    ),
                    Err(e) => fail(&format!("writing {out_path}: {e}")),
                }
            }
            write_counters(&engine, "run");
            finish_engine(&engine);
        }
        "merge" => {
            if positional.is_empty() {
                fail("merge <dir>... (at least one shard store directory)");
            }
            let exps = parse_experiments(&experiment);
            let shards: Vec<Store> = positional
                .iter()
                .map(|d| {
                    Store::open_existing(d)
                        .unwrap_or_else(|e| fail(&format!("opening store {d}: {e}")))
                })
                .collect();
            // union the shard stores into the local persistent store too,
            // so the merge host is warm for future runs
            if let Some(local) = open_store() {
                let mut imported = 0;
                for s in &shards {
                    imported += local
                        .merge_from(s)
                        .unwrap_or_else(|e| fail(&format!("merging into local store: {e}")));
                }
                if let Err(e) = local.write_manifest() {
                    eprintln!("warning: writing store manifest: {e}");
                }
                eprintln!(
                    "imported {imported} new records (measurement + trace tiers) into {}",
                    local.root().display()
                );
            }
            let json = coordinator::merge_bench_json(&shards, &exps, scale, &cfg, use_des)
                .unwrap_or_else(|e| fail(&e));
            match std::fs::write(&out_path, &json) {
                Ok(()) => eprintln!("wrote {out_path} (merged from {} store(s))", shards.len()),
                Err(e) => fail(&format!("writing {out_path}: {e}")),
            }
        }
        "sweep" => {
            // bench names were validated when `--benches` was parsed; the
            // default list is registry-known
            let engine = mk_engine(jobs);
            let cells: Vec<coordinator::Cell> = benches
                .iter()
                .flat_map(|b| {
                    depths
                        .iter()
                        .map(|d| coordinator::Cell::new(b, Variant::FeedForward { depth: *d }, scale))
                        .collect::<Vec<_>>()
                })
                .collect();
            let _ = engine.run_cells(&cells);
            let names: Vec<&str> = benches.iter().map(|b| b.as_str()).collect();
            save(&engine.depth_sweep(&names, scale, &depths), "depth_sweep");
            match engine.write_bench_json(std::path::Path::new(&out_path), scale, &[]) {
                Ok(()) => eprintln!(
                    "wrote {out_path} ({} simulated, {} trace runs, {} trace hits)",
                    engine.simulations(),
                    engine.trace_runs(),
                    engine.trace_hits(),
                ),
                Err(e) => fail(&format!("writing {out_path}: {e}")),
            }
            write_counters(&engine, "sweep");
            finish_engine(&engine);
        }
        "tune" => {
            let engine = mk_engine(jobs);
            let req = coordinator::TuneRequest {
                benches: benches.clone(),
                policy,
                budget,
                replication,
                scale,
                reference: !no_ref,
            };
            let report = coordinator::run_tune(&engine, &req).unwrap_or_else(|e| fail(&e));
            save(&report.table(), "tune");
            // the TuneReport artifact deliberately excludes live counters,
            // so a warm-store rerun is byte-identical to the cold run
            let tune_path = if out_set { out_path.clone() } else { "TUNE.json".to_string() };
            match pipefwd::util::json::write_file_atomic(
                std::path::Path::new(&tune_path),
                &report.to_json(),
            ) {
                Ok(()) => eprintln!(
                    "wrote {tune_path} ({} bench(es), {} policy, {} probes, \
                     simulations: {}, trace runs: {}, trace hits: {}, store hits: {})",
                    report.outcomes.len(),
                    report.policy.label(),
                    report.total_probes(),
                    engine.simulations(),
                    engine.trace_runs(),
                    engine.trace_hits(),
                    engine.store_hits(),
                ),
                Err(e) => fail(&format!("writing {tune_path}: {e}")),
            }
            write_counters(&engine, "tune");
            finish_engine(&engine);
        }
        "report" => {
            if let Some((old_path, new_path)) = &diff {
                let failures = report_diff(old_path, new_path, threshold);
                if failures > 0 {
                    eprintln!(
                        "FAIL: {failures} gate failure(s) — regressions above {threshold}% \
                         or configurations lost (old: {old_path}, new: {new_path})"
                    );
                    std::process::exit(1);
                }
                return;
            }
            match std::fs::read_to_string(&in_path) {
                Ok(text) => {
                    let doc = pipefwd::util::json::parse(&text)
                        .unwrap_or_else(|e| fail(&format!("parsing {in_path}: {e}")));
                    match format.as_str() {
                        "json" => print!("{}", doc.to_pretty()),
                        "table" => {
                            let ms: Vec<coordinator::Measurement> = doc
                                .get("measurements")
                                .and_then(|m| m.as_array())
                                .unwrap_or_else(|| fail(&format!("{in_path}: no measurements array")))
                                .iter()
                                .filter_map(coordinator::Measurement::from_json)
                                .collect();
                            let t = measurements_table(&format!("Results sink: {in_path}"), &ms);
                            print!("{}", t.to_markdown());
                        }
                        other => fail(&format!("unknown --format `{other}` (table|json)")),
                    }
                }
                Err(read_err) => {
                    // the DEFAULT sink file is absent: render from the
                    // persistent store instead of erroring — restricted to
                    // the requested scale and estimator, since the store
                    // accumulates entries across both. An explicitly
                    // requested --in file, or any error other than
                    // not-found, still fails: silently substituting store
                    // data for a named file would hand scripts wrong data.
                    if in_set || read_err.kind() != std::io::ErrorKind::NotFound {
                        fail(&format!("reading {in_path}: {read_err}"));
                    }
                    // read-only path: open the store only if it already
                    // exists (no create_dir_all side effect)
                    let store = (!no_cache)
                        .then(|| Store::open_existing(Store::resolve_dir(cache_dir.as_deref())).ok())
                        .flatten()
                        .unwrap_or_else(|| {
                            fail(&format!(
                                "reading {in_path}: {read_err} (run `pipefwd run` first)"
                            ))
                        });
                    let ms =
                        store.measurements_filtered(coordinator::scale_label(scale), use_des);
                    if ms.is_empty() {
                        fail(&format!(
                            "reading {in_path}: {read_err} (and store {} has no {} {} \
                             measurements — run `pipefwd run` first)",
                            store.root().display(),
                            coordinator::scale_label(scale),
                            if use_des { "DES" } else { "analytic" },
                        ));
                    }
                    match format.as_str() {
                        "json" => print!("{}", coordinator::bench_doc(scale, &[], &ms)),
                        "table" => {
                            let title = format!(
                                "Results sink: store {} ({}, {})",
                                store.root().display(),
                                coordinator::scale_label(scale),
                                if use_des { "des" } else { "analytic" },
                            );
                            print!("{}", measurements_table(&title, &ms).to_markdown());
                        }
                        other => fail(&format!("unknown --format `{other}` (table|json)")),
                    }
                }
            }
        }
        "store" => {
            let action = positional
                .first()
                .map(String::as_str)
                .unwrap_or_else(|| fail("store <stats|gc> (see `pipefwd` usage)"));
            // operate on the store in place: it must already exist —
            // fabricating an empty one just to stat or gc it would hide a
            // typo'd --cache-dir
            let dir = Store::resolve_dir(cache_dir.as_deref());
            let store = Store::open_existing(&dir)
                .unwrap_or_else(|e| fail(&format!("opening store {}: {e}", dir.display())));
            match action {
                "stats" => {
                    let stats = store.stats();
                    match format.as_str() {
                        "json" => print!("{}", stats.to_json().to_pretty()),
                        "table" => {
                            let schema = coordinator::store::STORE_SCHEMA;
                            let mut t = pipefwd::report::Table::new(
                                &format!("Store {} ({schema})", dir.display()),
                                &["Tier", "Records", "Bytes"],
                            );
                            for (name, tier) in [
                                ("entries", stats.entries),
                                ("traces", stats.traces),
                                ("profiles (pool)", stats.profiles),
                            ] {
                                t.row(vec![
                                    name.into(),
                                    tier.count.to_string(),
                                    tier.bytes.to_string(),
                                ]);
                            }
                            print!("{}", t.to_markdown());
                            println!(
                                "\nprofile refs: {} across {} pooled profiles \
                                 (dedup ratio {:.2}x)",
                                stats.profile_refs,
                                stats.profiles.count,
                                stats.dedup_ratio(),
                            );
                        }
                        other => fail(&format!("unknown --format `{other}` (table|json)")),
                    }
                }
                "gc" => {
                    // the reachable set is a pure grid/ladder replay (IR
                    // transforms only) — same move as `merge`, zero
                    // simulation
                    let reachable = coordinator::reachable_keys(&cfg);
                    let report = store
                        .gc(&reachable.entries, &reachable.traces, dry_run)
                        .unwrap_or_else(|e| fail(&format!("store gc: {e}")));
                    let verb = if dry_run { "would remove" } else { "removed" };
                    let removed_col = if dry_run { "Would remove" } else { "Removed" };
                    let mut t = pipefwd::report::Table::new(
                        &format!(
                            "Store gc {}{}",
                            dir.display(),
                            if dry_run { " (dry run)" } else { "" }
                        ),
                        &["Tier", "Kept", removed_col],
                    );
                    t.row(vec![
                        "entries".into(),
                        report.kept_entries.to_string(),
                        report.removed_entries.to_string(),
                    ]);
                    t.row(vec![
                        "traces".into(),
                        report.kept_traces.to_string(),
                        report.removed_traces.to_string(),
                    ]);
                    t.row(vec![
                        "profiles (pool)".into(),
                        report.kept_profiles.to_string(),
                        report.removed_profiles.to_string(),
                    ]);
                    print!("{}", t.to_markdown());
                    eprintln!(
                        "{verb} {} unreachable record(s){}",
                        report.removed_total(),
                        if dry_run { "" } else { "; MANIFEST.json rewritten" },
                    );
                }
                other => fail(&format!("unknown store action `{other}` (stats|gc)")),
            }
        }
        "table1" => save(&coordinator::table1(scale), "table1"),
        "table2" => save(&coordinator::table2(scale, &cfg), "table2"),
        "figure4" => save(&coordinator::figure4(scale, &cfg), "figure4"),
        "table3" => save(&coordinator::table3(scale, &cfg), "table3"),
        "intext" => save(&coordinator::intext(scale, &cfg), "intext"),
        "sweeps" => {
            let mut engine = Engine::new(cfg, jobs);
            if tuned {
                engine = engine.with_tuner(coordinator::TuneSpec { policy, budget });
            }
            let trio = ["fw", "hotspot", "mis"];
            save(&engine.depth_sweep(&trio, scale, &[1, 100, 1000]), "depth_sweep");
            save(&engine.pc_sweep(&trio, scale), "pc_sweep");
        }
        "vectors" => save(&coordinator::vector_study(scale, &cfg), "vector_study"),
        "micro-family" => save(&coordinator::micro_family(scale, &cfg), "micro_family"),
        "headline" => {
            let h = coordinator::headline(scale, &cfg);
            println!(
                "max feed-forward speedup : {:.1}x   (paper: up to 65x)",
                h.max_ff_speedup
            );
            println!(
                "avg speedup (gainers)    : {:.1}x   (paper: ~20x average)",
                h.avg_ff_speedup_gainers
            );
            println!(
                "max with M2C2            : {:.1}x   (paper: up to 86x)",
                h.max_total_speedup
            );
        }
        "all" => {
            for t in coordinator::full_evaluation(scale, &cfg, csv) {
                print!("{}", t.to_markdown());
                println!();
            }
        }
        "report-kernel" => {
            let name = positional.first().unwrap_or_else(|| fail("report-kernel <bench>"));
            let w = by_name(name).unwrap_or_else(|| fail(&format!("unknown benchmark `{name}`")));
            for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
                match w.build(variant) {
                    Ok(app) => {
                        let union = app.union_program();
                        let rep = pipefwd::analysis::program_report(&union, &cfg);
                        println!("--- {} ---", variant.label());
                        print!("{}", rep.render());
                    }
                    Err(e) => println!("--- {} --- infeasible: {e}", variant.label()),
                }
            }
        }
        "source" => {
            let name = positional.first().unwrap_or_else(|| fail("source <bench>"));
            let w = by_name(name).unwrap_or_else(|| fail(&format!("unknown benchmark `{name}`")));
            for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
                match w.build(variant) {
                    Ok(app) => {
                        println!("// ===== {} =====", variant.label());
                        for u in &app.units {
                            print!("{}", pipefwd::ir::pretty::program_to_string(u));
                            println!();
                        }
                    }
                    Err(e) => println!("// ===== {} ===== infeasible: {e}", variant.label()),
                }
            }
        }
        "golden" => {
            let rt = pipefwd::runtime::Runtime::open_default().unwrap_or_else(|e| {
                eprintln!("cannot open artifacts: {e:#}");
                std::process::exit(1);
            });
            match pipefwd::runtime::golden::check_all(&rt) {
                Ok(results) => {
                    for (name, d) in results {
                        println!("{name:>18}: max |diff| vs PJRT golden = {d:.2e}  OK");
                    }
                }
                Err(e) => {
                    eprintln!("golden validation FAILED: {e:#}");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}

/// Parse the `--experiment` value: `all` or a comma-separated id list.
fn parse_experiments(s: &str) -> Vec<ExperimentId> {
    if s.eq_ignore_ascii_case("all") {
        return ExperimentId::all().to_vec();
    }
    s.split(',')
        .map(|e| {
            ExperimentId::parse(e.trim())
                .unwrap_or_else(|| fail(&format!("unknown experiment `{e}` (E1..E7)")))
        })
        .collect()
}

/// Parse `I/N` (1-based) for `--shard`.
fn parse_shard(s: &str) -> Option<(usize, usize)> {
    let (i, n) = s.split_once('/')?;
    let i = i.trim().parse::<usize>().ok()?;
    let n = n.trim().parse::<usize>().ok()?;
    (n > 0 && (1..=n).contains(&i)).then_some((i, n))
}

/// The `report --format table` rendering, shared by the file and store
/// paths.
fn measurements_table(
    title: &str,
    ms: &[coordinator::Measurement],
) -> pipefwd::report::Table {
    let mut t = pipefwd::report::Table::new(
        title,
        &[
            "Benchmark", "Variant", "Scale", "Time (ms)", "Logic (%)", "BRAM", "Max II",
            "Max BW (MB/s)", "Launches",
        ],
    );
    for m in ms {
        t.row(vec![
            m.workload.clone(),
            m.variant.clone(),
            m.scale.clone(),
            pipefwd::report::ms(m.seconds),
            format!("{:.2}", m.logic_pct),
            m.brams.to_string(),
            m.max_ii.to_string(),
            pipefwd::report::mbps(m.max_bw),
            m.launches.to_string(),
        ]);
    }
    t
}

/// `report --diff`: compare two results sinks configuration by
/// configuration and render a markdown table (readable in a CI job
/// summary). Returns the number of gate failures: modelled-performance
/// regressions whose slowdown exceeds `threshold` percent, plus
/// configurations that vanished from the new sink (silent loss of
/// coverage — e.g. a variant that started failing validation).
fn report_diff(old_path: &str, new_path: &str, threshold: f64) -> usize {
    let load = |path: &str| -> Vec<coordinator::Measurement> {
        let doc = pipefwd::util::json::read_file(std::path::Path::new(path))
            .unwrap_or_else(|e| fail(&e));
        doc.get("measurements")
            .and_then(|m| m.as_array())
            .unwrap_or_else(|| fail(&format!("{path}: no measurements array")))
            .iter()
            .filter_map(coordinator::Measurement::from_json)
            .collect()
    };
    let old = load(old_path);
    let new = load(new_path);
    let mut old_by_key = std::collections::HashMap::new();
    for m in &old {
        old_by_key.insert((m.workload.clone(), m.variant.clone(), m.scale.clone()), m);
    }

    let mut t = pipefwd::report::Table::new(
        &format!("Modelled-performance diff (threshold {threshold}%)"),
        &["Benchmark", "Variant", "Scale", "Old (ms)", "New (ms)", "Delta (%)", "Status"],
    );
    let mut regressions = 0;
    let mut added = 0;
    for m in &new {
        let key = (m.workload.clone(), m.variant.clone(), m.scale.clone());
        let Some(o) = old_by_key.get(&key) else {
            added += 1;
            continue;
        };
        let delta_pct = if o.seconds > 0.0 {
            (m.seconds / o.seconds - 1.0) * 100.0
        } else if m.seconds > 0.0 {
            f64::INFINITY // 0 -> nonzero: unambiguously slower
        } else {
            0.0
        };
        let status = if delta_pct > threshold {
            regressions += 1;
            "REGRESSION"
        } else if delta_pct < -threshold {
            "improved"
        } else {
            "ok"
        };
        t.row(vec![
            m.workload.clone(),
            m.variant.clone(),
            m.scale.clone(),
            pipefwd::report::ms(o.seconds),
            pipefwd::report::ms(m.seconds),
            format!("{delta_pct:+.2}"),
            status.into(),
        ]);
    }
    // configurations that vanished are a gate failure too: a variant that
    // silently stopped producing measurements must not pass as "no
    // regressions"
    let new_keys: std::collections::HashSet<(String, String, String)> = new
        .iter()
        .map(|m| (m.workload.clone(), m.variant.clone(), m.scale.clone()))
        .collect();
    let mut removed = 0;
    for m in &old {
        if !new_keys.contains(&(m.workload.clone(), m.variant.clone(), m.scale.clone())) {
            removed += 1;
            t.row(vec![
                m.workload.clone(),
                m.variant.clone(),
                m.scale.clone(),
                pipefwd::report::ms(m.seconds),
                "-".into(),
                "-".into(),
                "REMOVED".into(),
            ]);
        }
    }
    print!("{}", t.to_markdown());
    println!(
        "\n{} configuration(s) compared, {regressions} regression(s) > {threshold}%, \
         {added} new, {removed} removed",
        t.rows.len() - removed
    );
    regressions + removed
}
