//! Integration tests for the execution substrate: interpreter semantics
//! under concurrency, the compiler-report pipeline, and the performance
//! model's paper-shape behaviours at integration granularity.

use pipefwd::analysis::program_report;
use pipefwd::ir::build::*;
use pipefwd::ir::{KernelKind, Program, Ty};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::sim::exec::{run_group, ExecOptions};
use pipefwd::sim::perf::PerfModel;
use pipefwd::transform::{feedforward, Variant};
use pipefwd::workloads::{by_name, run_workload, Scale};

/// The NW pipe-depth subtlety (see workloads::nw): with depth below the
/// row width the FF pair computes correct results; the previous-row loads
/// observe completed writes because the memory kernel's lead is bounded.
#[test]
fn nw_depth_safety_boundary() {
    let cfg = DeviceConfig::pac_a10();
    // depth 1 and 100 are < row width (63 interior cells at Tiny): wait —
    // 100 > 63, so at Tiny only depth 1 is guaranteed safe; use it.
    let h = run_workload(
        by_name("nw").unwrap().as_ref(),
        Variant::FeedForward { depth: 1 },
        Scale::Tiny,
        &cfg,
    );
    assert!(h.is_ok(), "{}", h.err().unwrap_or_default());
}

/// The compiler report renders end-to-end for a real benchmark and shows
/// the paper's headline II transition (FW 285 -> 1).
#[test]
fn fw_report_shows_ii_transition() {
    let cfg = DeviceConfig::pac_a10();
    let fw = by_name("fw").unwrap();
    let base = fw.build(Variant::Baseline).unwrap();
    let rep = program_report(&base.union_program(), &cfg);
    assert_eq!(rep.max_ii(), 285);
    assert!(rep.render().contains("II = 285"));

    let ff = fw.build(Variant::FeedForward { depth: 1 }).unwrap();
    let rep2 = program_report(&ff.union_program(), &cfg);
    assert_eq!(rep2.max_ii(), 1);
    // prefetching LSUs unlocked by the split (§4.2 FW discussion)
    let mem = &rep2.kernels[0];
    assert!(mem.prefetching_loads() >= 1);
}

/// Concurrent kernels communicating through a chain of pipes (producer ->
/// filter -> consumer): a 3-stage pipeline beyond the canonical pair.
#[test]
fn three_stage_pipeline_executes() {
    let producer = KernelBuilder::new("prod", KernelKind::SingleWorkItem)
        .buf_ro("a", Ty::F32)
        .scalar("n", Ty::I32)
        .body(vec![for_("i", i(0), p("n"), vec![pwrite("c0", ld("a", v("i")))])])
        .finish();
    let filter = KernelBuilder::new("filt", KernelKind::SingleWorkItem)
        .scalar("n", Ty::I32)
        .body(vec![for_(
            "i",
            i(0),
            p("n"),
            vec![pread("x", Ty::F32, "c0"), pwrite("c1", v("x") * f(2.0))],
        )])
        .finish();
    let consumer = KernelBuilder::new("cons", KernelKind::SingleWorkItem)
        .buf_wo("o", Ty::F32)
        .scalar("n", Ty::I32)
        .body(vec![for_(
            "i",
            i(0),
            p("n"),
            vec![pread("y", Ty::F32, "c1"), store("o", v("i"), v("y") + f(1.0))],
        )])
        .finish();
    let prog = Program {
        name: "pipe3".into(),
        kernels: vec![producer, filter, consumer],
        pipes: vec![
            pipefwd::ir::PipeDecl { name: "c0".into(), ty: Ty::F32, depth: 2 },
            pipefwd::ir::PipeDecl { name: "c1".into(), ty: Ty::F32, depth: 2 },
        ],
    };
    assert_eq!(pipefwd::ir::validate_program(&prog), Ok(()));
    let mut img = pipefwd::sim::mem::MemoryImage::new();
    img.add_f32s("a", &[1.0, 2.0, 3.0, 4.0]).add_zeros("o", Ty::F32, 4).set_i("n", 4);
    run_group(&prog, &img, &ExecOptions::default()).unwrap();
    assert_eq!(img.buf("o").unwrap().to_f32s(), vec![3.0, 5.0, 7.0, 9.0]);
}

/// Mismatched pipe traces surface as PipeClosed errors, not hangs: the
/// producer writes fewer tokens than the consumer wants.
#[test]
fn token_mismatch_is_detected() {
    let producer = KernelBuilder::new("prod", KernelKind::SingleWorkItem)
        .scalar("n", Ty::I32)
        .body(vec![for_("i", i(0), p("n") - i(1), vec![pwrite("c0", v("i"))])])
        .finish();
    let consumer = KernelBuilder::new("cons", KernelKind::SingleWorkItem)
        .buf_wo("o", Ty::I32)
        .scalar("n", Ty::I32)
        .body(vec![for_(
            "i",
            i(0),
            p("n"),
            vec![pread("x", Ty::I32, "c0"), store("o", v("i"), v("x"))],
        )])
        .finish();
    let prog = Program {
        name: "mismatch".into(),
        kernels: vec![producer, consumer],
        pipes: vec![pipefwd::ir::PipeDecl { name: "c0".into(), ty: Ty::I32, depth: 1 }],
    };
    let mut img = pipefwd::sim::mem::MemoryImage::new();
    img.add_zeros("o", Ty::I32, 8).set_i("n", 8);
    let err = run_group(&prog, &img, &ExecOptions::default()).unwrap_err();
    assert!(matches!(err, pipefwd::sim::exec::ExecError::PipeClosed { .. }));
}

/// Congestion shape: four irregular streams on one DRAM saturate — the
/// modelled time for the 4-way split is not 4x better (the paper's
/// plateau-past-two-producers effect, E4d).
#[test]
fn replication_plateaus_on_irregular_traffic() {
    let cfg = DeviceConfig::pac_a10();
    let k = KernelBuilder::new("gather", KernelKind::SingleWorkItem)
        .buf_ro("idx", Ty::I32)
        .buf_ro("a", Ty::F32)
        .buf_wo("o", Ty::F32)
        .scalar("n", Ty::I32)
        .body(vec![for_(
            "i",
            i(0),
            p("n"),
            vec![store("o", v("i"), ld("a", ld("idx", v("i"))))],
        )])
        .finish();
    let n = 60_000usize;
    let image = || {
        let mut m = pipefwd::sim::mem::MemoryImage::new();
        let idx = pipefwd::util::rng::Rng::new(7).permutation(n);
        m.add_i64s("idx", &idx).add_f32s("a", &vec![1.0; n]).add_zeros("o", Ty::F32, n);
        m.set_i("n", n as i64);
        m
    };
    let mut times = vec![];
    for variant in [
        Variant::FeedForward { depth: 1 },
        Variant::MxCx { parts: 2, depth: 1 },
        Variant::MxCx { parts: 4, depth: 1 },
    ] {
        let prog = pipefwd::transform::apply_variant(&k, variant).unwrap();
        let img = image();
        let run = run_group(&prog, &img, &ExecOptions::default()).unwrap();
        let m = PerfModel::new(&prog, &cfg).estimate(&run.profiles);
        times.push(m.seconds);
    }
    let s2 = times[0] / times[1];
    let s4 = times[0] / times[2];
    assert!(s2 < 1.5, "m2c2 on DRAM-bound gather should be ~flat, got {s2}");
    assert!(s4 < s2 * 1.3 + 0.2, "m4c4 must not keep scaling: {s4} vs {s2}");
}

/// Feed-forward on an already-pipelined kernel costs a little (the 0.85x
/// Hotspot shape) — directly at the perf-model level.
#[test]
fn ff_overhead_on_streaming_kernel() {
    let cfg = DeviceConfig::pac_a10();
    let k = KernelBuilder::new("s", KernelKind::SingleWorkItem)
        .buf_ro("a", Ty::F32)
        .buf_ro("b", Ty::F32)
        .buf_wo("o", Ty::F32)
        .scalar("n", Ty::I32)
        .body(vec![for_(
            "i",
            i(0),
            p("n"),
            vec![store("o", v("i"), ld("a", v("i")) + ld("b", v("i")))],
        )])
        .finish();
    let n = 50_000;
    let image = || {
        let mut m = pipefwd::sim::mem::MemoryImage::new();
        m.add_f32s("a", &vec![1.0; n]).add_f32s("b", &vec![2.0; n]).add_zeros("o", Ty::F32, n);
        m.set_i("n", n as i64);
        m
    };
    let base = Program::single(k.clone());
    let img = image();
    let r = run_group(&base, &img, &ExecOptions::default()).unwrap();
    let t_base = PerfModel::new(&base, &cfg).estimate(&r.profiles).seconds;

    let ff = feedforward(&k, 1).unwrap();
    let img = image();
    let r = run_group(&ff, &img, &ExecOptions::default()).unwrap();
    let t_ff = PerfModel::new(&ff, &cfg).estimate(&r.profiles).seconds;
    let speedup = t_base / t_ff;
    assert!(speedup > 0.7 && speedup < 1.0, "streaming ff speedup = {speedup}");
}
