//! PJRT runtime integration: load the AOT artifacts, execute them, and
//! cross-validate the IR interpreter's numerics against the JAX/Pallas
//! golden implementations (requires `make artifacts`; skipped otherwise).

use pipefwd::runtime::{golden, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime tests: {e:#} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn manifest_covers_expected_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.names();
    for expected in ["hotspot", "fw", "backprop_out", "knn", "pagerank", "mis_neighbor_min"] {
        assert!(names.contains(&expected), "missing artifact {expected}");
    }
}

#[test]
fn artifact_executes_with_correct_shapes() {
    let Some(rt) = runtime_or_skip() else { return };
    let spec = rt.spec("knn").unwrap().clone();
    assert_eq!(spec.inputs[0].dims, vec![1024, 8]);
    let pts = vec![0.5f32; 1024 * 8];
    let q = vec![0.25f32; 8];
    let out = rt.run_f32("knn", &[pts, q]).unwrap();
    assert_eq!(out.len(), 1024);
    // every distance is 8 * 0.25^2 = 0.5
    for d in out {
        assert!((d - 0.5).abs() < 1e-5);
    }
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    assert!(rt.run_f32("knn", &[vec![0.0; 8]]).is_err());
    assert!(rt.run_f32("nope", &[]).is_err());
}

#[test]
fn golden_hotspot() {
    let Some(rt) = runtime_or_skip() else { return };
    let d = golden::check_hotspot(&rt).unwrap();
    assert!(d < 1e-3);
}

#[test]
fn golden_fw() {
    let Some(rt) = runtime_or_skip() else { return };
    golden::check_fw(&rt).unwrap();
}

#[test]
fn golden_knn() {
    let Some(rt) = runtime_or_skip() else { return };
    golden::check_knn(&rt).unwrap();
}

#[test]
fn golden_pagerank() {
    let Some(rt) = runtime_or_skip() else { return };
    golden::check_pagerank(&rt).unwrap();
}

#[test]
fn golden_mis_neighbor_min() {
    let Some(rt) = runtime_or_skip() else { return };
    golden::check_mis_neighbor_min(&rt).unwrap();
}

/// The backprop artifacts encode the MXU forward pass + the explicit
/// Rodinia update: spot-check the training-step artifact reduces loss.
#[test]
fn backprop_artifact_training_step_descends() {
    let Some(rt) = runtime_or_skip() else { return };
    use pipefwd::util::rng::Rng;
    let mut rng = Rng::new(42);
    let mut gen = |n: usize, s: f32| -> Vec<f32> {
        (0..n).map(|_| rng.f32_range(-s, s)).collect()
    };
    let x = gen(32 * 64, 1.0);
    let w1 = gen(64 * 16, 0.1);
    let w2 = gen(16 * 8, 0.1);
    let target: Vec<f32> = (0..32 * 8).map(|_| 0.5f32).collect();

    let out0 = rt.run_f32("backprop_out", &[x.clone(), w1.clone(), w2.clone()]).unwrap();
    let w1b = rt
        .run_f32("backprop_w1", &[x.clone(), w1, w2.clone(), target.clone()])
        .unwrap();
    let out1 = rt.run_f32("backprop_out", &[x, w1b, w2]).unwrap();
    let loss = |o: &[f32]| -> f32 {
        o.iter().zip(&target).map(|(a, b)| (a - b) * (a - b)).sum::<f32>()
    };
    assert!(loss(&out1) < loss(&out0), "training step did not descend");
}
