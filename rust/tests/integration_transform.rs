//! Integration tests for the paper's worked examples (Fig. 2 / Fig. 3) and
//! the end-to-end transformation recipe on real benchmark kernels.

use pipefwd::analysis::report::KernelReport;
use pipefwd::ir::pretty::program_to_string;
use pipefwd::transform::examples::{fig2_kernel, fig3b_kernel};
use pipefwd::transform::{apply_variant, feedforward, ndrange_to_swi, Variant};
use pipefwd::workloads::{suite, Workload};

/// E5: the Fig. 2 transformation reproduces the paper's structure — the
/// printed memory kernel contains only channel writes and loads, the
/// compute kernel only channel reads and stores.
#[test]
fn fig2_printed_structure_matches_paper() {
    let ff = feedforward(&fig2_kernel(), 1).unwrap();
    let src = program_to_string(&ff);
    assert!(src.contains("#pragma OPENCL EXTENSION cl_intel_channels : enable"));
    // memory kernel: write_channel_intel per load; no stores to min_array
    let mem_src = pipefwd::ir::pretty::kernel_to_string(&ff.kernels[0]);
    assert!(mem_src.contains("write_channel_intel"));
    assert!(!mem_src.contains("min_array["));
    assert!(mem_src.contains("c_array["));
    // compute kernel: read_channel_intel, stores, no global loads
    let cmp_src = pipefwd::ir::pretty::kernel_to_string(&ff.kernels[1]);
    assert!(cmp_src.contains("read_channel_intel"));
    assert!(cmp_src.contains("min_array["));
    assert!(!cmp_src.contains("c_array["));
    assert!(!cmp_src.contains("col["));
}

/// E5: Fig. 3 — the DLCD moves to the compute kernel; the memory kernel
/// pipelines at II=1.
#[test]
fn fig3_dlcd_moves_to_compute_kernel() {
    let k = fig3b_kernel();
    let base = KernelReport::for_kernel(&k);
    assert!(base.loops.iter().any(|l| l.dlcd_var.is_some()));

    let ff = feedforward(&k, 1).unwrap();
    let mem = KernelReport::for_kernel(&ff.kernels[0]);
    let cmp = KernelReport::for_kernel(&ff.kernels[1]);
    assert!(mem.loops.iter().all(|l| l.dlcd_var.is_none()), "DLCD left in memory kernel");
    assert_eq!(mem.max_ii(), 1);
    assert!(cmp.loops.iter().any(|l| l.dlcd_var.is_some()), "DLCD lost entirely");
}

/// NDRange -> SWI -> feed-forward composes (paper step 1 feeding step 6).
#[test]
fn ndrange_pipeline_composes() {
    use pipefwd::ir::build::*;
    use pipefwd::ir::{KernelKind, Ty};
    let nd = KernelBuilder::new("scale", KernelKind::NDRange)
        .buf_ro("a", Ty::F32)
        .buf_wo("o", Ty::F32)
        .body(vec![store("o", gid(), ld("a", gid()) * f(2.0))])
        .finish();
    let swi = ndrange_to_swi(&nd, "n");
    let ff = feedforward(&swi, 1).unwrap();
    assert_eq!(ff.kernels.len(), 2);
    assert_eq!(pipefwd::ir::validate_program(&ff), Ok(()));
}

/// Every suite benchmark builds every applicable variant, and the variant
/// matrix is consistent with `supports_replication`.
#[test]
fn variant_matrix_builds_for_all_benchmarks() {
    for w in suite() {
        for variant in [
            Variant::Baseline,
            Variant::FeedForward { depth: 1 },
            Variant::FeedForward { depth: 1000 },
        ] {
            let app = w.build(variant).unwrap_or_else(|e| {
                panic!("{}: {variant:?} failed: {e}", w.name());
            });
            for u in &app.units {
                pipefwd::ir::validate_program(u)
                    .unwrap_or_else(|e| panic!("{} {variant:?}: {e}", w.name()));
            }
        }
        let m2 = w.build(Variant::MxCx { parts: 2, depth: 1 });
        assert_eq!(m2.is_ok(), w.supports_replication(), "{}", w.name());
    }
}

/// Transformed kernels keep the paper's naming convention so reports are
/// readable.
#[test]
fn split_kernel_names_follow_convention() {
    let k = fig2_kernel();
    let prog = apply_variant(&k, Variant::MxCx { parts: 2, depth: 1 }).unwrap();
    let names: Vec<&str> = prog.kernels.iter().map(|k| k.name.as_str()).collect();
    assert_eq!(names, vec!["mis1_mem_r0", "mis1_cmp_r0", "mis1_mem_r1", "mis1_cmp_r1"]);
}

/// The paper's feasibility limitation: NW is rejected until privatized,
/// and privatization is discoverable through the public API.
#[test]
fn nw_limitation_workflow() {
    let nw = pipefwd::workloads::by_name("nw").unwrap();
    let k = &nw.kernels()[0];
    let err = feedforward(k, 1).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("loop-carried"), "unexpected error: {msg}");
    let fixed = pipefwd::transform::privatize(k).unwrap();
    assert!(feedforward(&fixed, 1).is_ok());
}
