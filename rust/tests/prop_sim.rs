//! Property tests over the simulator stack: performance-model sanity
//! (bound consistency, monotonicity), DES vs analytic agreement, and
//! profile/site-numbering invariants on random kernels.

use pipefwd::ir::Program;
use pipefwd::sim::device::DeviceConfig;
use pipefwd::sim::exec::{run_group, ExecOptions};
use pipefwd::sim::perf::PerfModel;
use pipefwd::transform::{apply_variant, Variant};
use pipefwd::util::testing::{check, gen_kernel};

#[test]
fn profile_sites_match_static_analysis() {
    check("sites_match", 40, |rng| {
        let g = gen_kernel(rng);
        let sites = pipefwd::analysis::select_lsus(&g.kernel);
        let img = g.image();
        let run = run_group(&Program::single(g.kernel.clone()), &img, &ExecOptions::default())
            .map_err(|e| e.to_string())?;
        if run.profiles[0].sites.len() != sites.len() {
            return Err(format!(
                "profile has {} sites, analysis {}",
                run.profiles[0].sites.len(),
                sites.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn makespan_at_least_both_bounds() {
    check("makespan_bounds", 40, |rng| {
        let g = gen_kernel(rng);
        let cfg = DeviceConfig::pac_a10();
        let prog = Program::single(g.kernel.clone());
        let img = g.image();
        let run = run_group(&prog, &img, &ExecOptions::default()).map_err(|e| e.to_string())?;
        let model = PerfModel::new(&prog, &cfg);
        let m = model.estimate(&run.profiles);
        let cb_max = m.per_kernel.iter().map(|(_, c)| *c).fold(0.0, f64::max);
        if m.cycles + 1e-6 < cb_max || m.cycles + 1e-6 < m.dram_cycles {
            return Err(format!(
                "makespan {} below bounds (cb {}, dram {})",
                m.cycles, cb_max, m.dram_cycles
            ));
        }
        if m.payload_bytes > m.dram_bytes + 1e-6 {
            return Err("payload exceeds DRAM occupancy".into());
        }
        Ok(())
    });
}

#[test]
fn des_within_factor_of_analytic() {
    check("des_vs_analytic", 25, |rng| {
        let g = gen_kernel(rng);
        let cfg = DeviceConfig::pac_a10();
        let prog = apply_variant(&g.kernel, Variant::FeedForward { depth: 4 })
            .map_err(|e| e.to_string())?;
        let img = g.image();
        let run = run_group(&prog, &img, &ExecOptions::default()).map_err(|e| e.to_string())?;
        let model = PerfModel::new(&prog, &cfg);
        let a = model.estimate(&run.profiles);
        let d = pipefwd::sim::des::simulate(&prog, &model, &run.profiles, &cfg, 16);
        let ratio = d.cycles / a.cycles;
        if !(0.5..=2.5).contains(&ratio) {
            return Err(format!("DES/analytic ratio {ratio}"));
        }
        Ok(())
    });
}

#[test]
fn more_traffic_never_modelled_faster() {
    check("monotone_in_work", 25, |rng| {
        let g = gen_kernel(rng);
        let cfg = DeviceConfig::pac_a10();
        let prog = Program::single(g.kernel.clone());
        let model = PerfModel::new(&prog, &cfg);

        let img_small = g.image();
        let run_small =
            run_group(&prog, &img_small, &ExecOptions::default()).map_err(|e| e.to_string())?;
        let t_small = model.estimate(&run_small.profiles).cycles;

        // run twice on the same image: accumulated profile = 2x traffic
        let img2 = g.image();
        let r1 = run_group(&prog, &img2, &ExecOptions::default()).map_err(|e| e.to_string())?;
        let r2 = run_group(&prog, &img2, &ExecOptions::default()).map_err(|e| e.to_string())?;
        let mut merged = r1.profiles[0].clone();
        merged.merge(&r2.profiles[0]);
        let t_double = model.estimate(std::slice::from_ref(&merged)).cycles;

        if t_double + 1e-6 < t_small {
            return Err(format!("2x work modelled faster: {t_double} < {t_small}"));
        }
        Ok(())
    });
}

#[test]
fn interpreter_is_deterministic_across_runs() {
    check("deterministic", 25, |rng| {
        let g = gen_kernel(rng);
        let prog = apply_variant(&g.kernel, Variant::MxCx { parts: 2, depth: 1 })
            .map_err(|e| e.to_string())?;
        let img1 = g.image();
        let img2 = g.image();
        run_group(&prog, &img1, &ExecOptions::default()).map_err(|e| e.to_string())?;
        run_group(&prog, &img2, &ExecOptions::default()).map_err(|e| e.to_string())?;
        if img1.buf("out").unwrap().to_f32s() != img2.buf("out").unwrap().to_f32s() {
            return Err("concurrent execution nondeterministic".into());
        }
        Ok(())
    });
}

#[test]
fn depth_changes_do_not_change_results_or_tokens() {
    check("depth_invariance", 25, |rng| {
        let g = gen_kernel(rng);
        let mut token_counts = vec![];
        let mut outs = vec![];
        for depth in [1usize, 7, 100] {
            let prog = apply_variant(&g.kernel, Variant::FeedForward { depth })
                .map_err(|e| e.to_string())?;
            let img = g.image();
            let run = run_group(&prog, &img, &ExecOptions::default()).map_err(|e| e.to_string())?;
            token_counts.push(run.profiles.iter().map(|p| p.pipe_writes).sum::<u64>());
            outs.push(img.buf("out").unwrap().to_f32s());
        }
        if token_counts.windows(2).any(|w| w[0] != w[1]) {
            return Err(format!("token counts vary with depth: {token_counts:?}"));
        }
        if outs.windows(2).any(|w| w[0] != w[1]) {
            return Err("results vary with depth".into());
        }
        Ok(())
    });
}
