//! PR-9 robustness: the deterministic fault-injection harness and the
//! recovery machinery it exists to prove. Fault state is process-global
//! (`util::fault`), so every test here serializes on one mutex and
//! disarms on exit — this binary is its own process, so arming a plan
//! here never leaks into the library's unit tests or the other
//! integration binaries.
//!
//! The centerpiece is the seeded soak: the full E4 grid driven through
//! `pipefwd serve` and the retrying `net::Client` while a bounded fault
//! schedule fires at every site — connections dropped at accept,
//! requests dropped mid-read, responses truncated mid-stream, an engine
//! worker panicking under claim, store reads garbled and store writes
//! torn — plus a daemon kill-and-restart on the same address and store
//! directory mid-grid. The acceptance bar: the reassembled sink is
//! byte-identical to a fault-free serial run, with nonzero `retries`
//! and `journal_replays` proving the failures actually happened and
//! were recovered, and zero `journal/` intents left on disk.
//!
//! PR-10 grows the soak a fleet leg: three shard engines push their
//! stores through `store_push` into a central daemon whose byte budget
//! is half the cold-store footprint, under an all-sites plan that now
//! includes `store.evict` — the exchange must evict, heal an
//! interrupted eviction across a restart, hold the budget invariant
//! after every push, and still merge byte-identical.

use pipefwd::coordinator::{grid_for, net, service, Engine, ExperimentId, Service, ServiceRequest, Store};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::fault::{self, FaultPlan};
use pipefwd::workloads::Scale;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// One plan at a time: `util::fault` is process-global state.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Holds the serialization lock and disarms the plan on drop, so a
/// failing test cannot leave a live schedule behind for the next one
/// (the lock recovers from poison for the same reason).
struct Armed(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for Armed {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn arm(spec: &str) -> Armed {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::install(FaultPlan::parse(spec).unwrap_or_else(|e| panic!("{e}")));
    Armed(guard)
}

/// The same plan replays the same verdict at every call index, and a
/// limited rule never fires past its cap — the property every soak
/// assertion leans on.
#[test]
fn same_plan_replays_the_same_schedule_and_respects_caps() {
    let spec = "seed=11;store.write=0.5x6";
    let _armed = arm(spec);
    let first: Vec<bool> = (0..64).map(|_| fault::fire("store.write")).collect();
    let fired = first.iter().filter(|b| **b).count();
    assert!(fired > 0, "a 50% rule over 64 calls must fire at least once");
    assert!(fired <= 6, "the x6 cap bounds total fires, got {fired}");
    assert_eq!(fault::fired_total(), fired as u64);

    // reinstall resets the stream: the verdict sequence is identical
    fault::install(FaultPlan::parse(spec).unwrap());
    let second: Vec<bool> = (0..64).map(|_| fault::fire("store.write")).collect();
    assert_eq!(first, second, "same plan, same schedule");

    // a different seed draws a different schedule
    fault::install(FaultPlan::parse("seed=12;store.write=0.5x6").unwrap());
    let third: Vec<bool> = (0..64).map(|_| fault::fire("store.write")).collect();
    assert_ne!(first, third, "the seed must select the schedule");
}

/// Each site draws from its own stream: interleaving calls at another
/// site must not perturb this site's verdict sequence. (Arming one
/// fault never changes which calls another fault hits.)
#[test]
fn sites_draw_from_independent_streams() {
    let spec = "seed=9;store.read=0.5;net.write=0.5";
    let _armed = arm(spec);
    let solo: Vec<bool> = (0..32).map(|_| fault::fire("store.read")).collect();

    fault::install(FaultPlan::parse(spec).unwrap());
    let interleaved: Vec<bool> = (0..32)
        .map(|_| {
            let v = fault::fire("store.read");
            let _ = fault::fire("net.write"); // burns net.write's stream only
            v
        })
        .collect();
    assert_eq!(solo, interleaved, "store.read's stream must ignore net.write draws");
}

/// `install_from` with an explicit spec (the `--fault-plan` path) arms
/// the process and honors the cap.
#[test]
fn install_from_explicit_spec_arms_and_caps() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::install_from(Some("seed=3;engine.panic=always x2")).unwrap();
    let _armed = Armed(guard);
    assert!(fault::active());
    assert!(fault::fire("engine.panic"));
    assert!(fault::fire("engine.panic"));
    assert!(!fault::fire("engine.panic"), "the x2 cap must exhaust");
    assert!(!fault::fire("store.write"), "unarmed sites never fire");
    assert_eq!(fault::fired_total(), 2);
}

/// An installed-but-empty plan is byte-for-byte free: same sink, same
/// counters, zero fires — the "effectively free when disabled" half of
/// the harness contract.
#[test]
fn empty_plan_leaves_sink_and_counters_identical() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::clear();
    let _armed = Armed(guard);

    let exps = vec![ExperimentId::E2];
    let cells = grid_for(&exps, Scale::Tiny);

    let plain = Engine::new(DeviceConfig::pac_a10(), 1);
    let _ = plain.run_cells(&cells);

    fault::install(FaultPlan::parse("seed=99").unwrap()); // no rules
    assert!(!fault::active(), "a rule-free plan must stay disarmed");
    let under_plan = Engine::new(DeviceConfig::pac_a10(), 1);
    let _ = under_plan.run_cells(&cells);

    assert_eq!(
        plain.bench_json(Scale::Tiny, &exps),
        under_plan.bench_json(Scale::Tiny, &exps),
        "an empty plan must not move a byte of the sink"
    );
    assert_eq!(plain.simulations(), under_plan.simulations());
    assert_eq!(fault::fired_total(), 0);
}

fn soak_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pipefwd-faults-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Reconstruct the exact on-disk state a daemon killed mid-`put_trace`
/// leaves behind: the `journal/` intent plus a torn trace document.
/// (An in-process test cannot genuinely die between two writes, so the
/// soak reproduces the crash artifact through the documented journal
/// format — `docs/RELIABILITY.md` — and lets the restarted store heal
/// it for real.)
fn leave_interrupted_put_trace(store_dir: &std::path::Path) {
    let key = "00000000000000aa";
    let intent = format!(
        "{{\"schema\": \"pipefwd-journal-v1\", \"op\": \"put_trace\", \
         \"key\": \"{key}\", \"files\": [\"traces/{key}.json\"]}}"
    );
    std::fs::write(
        store_dir.join("journal").join(format!("put_trace-{key}.json")),
        intent,
    )
    .unwrap();
    // a trace file cut mid-write: parses as nothing, must be discarded
    std::fs::write(
        store_dir.join("traces").join(format!("{key}.json")),
        b"{\"schema\": \"pipefwd-store-v6\", \"kind\": \"trace\"",
    )
    .unwrap();
}

/// The PR-9 acceptance soak. Every injection site fires under a seeded,
/// bounded schedule while the E4 grid flows through serve + Client,
/// with a daemon kill-and-restart (same port, same store) mid-grid:
///
/// 1. fault-free serial reference run → the expected sink bytes;
/// 2. daemon A, schedule armed: a sweep request survives a dropped
///    accept, a dropped read, two truncated responses, and a worker
///    panic — the client's retry policy eats all of them;
/// 3. daemon A is killed; the store is left holding an interrupted
///    `put_trace` (intent + torn trace), the crash the journal exists
///    for;
/// 4. daemon B binds the *same* address over the *same* store — open
///    heals the journal — and serves the full E4 grid.
///
/// The sink must be byte-identical to the reference, with
/// `retries > 0`, `journal_replays > 0`, and an empty journal at exit.
#[test]
fn seeded_soak_is_byte_identical_through_faults_and_restart() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::clear();
    let _armed = Armed(guard);

    let base = soak_dir("soak");
    let store_dir = base.join("store");

    // 1. the fault-free truth, before any plan is armed
    let exps = vec![ExperimentId::E4];
    let cells = grid_for(&exps, Scale::Tiny);
    let reference = Engine::new(DeviceConfig::pac_a10(), 1);
    let _ = reference.run_cells(&cells);
    let expect = reference.bench_json(Scale::Tiny, &exps);

    // bounded `always` rules: exact fire counts, all burned early, so
    // the run is deterministic and guaranteed to finish armed-then-clean
    fault::install(
        FaultPlan::parse(
            "seed=2026;net.accept=always x1;net.read=always x1;net.write=always x2;\
             engine.panic=always x1;store.read=always x2;store.write=always x2",
        )
        .unwrap(),
    );

    // fast, deterministic backoff so the soak spends its time computing,
    // not sleeping; generous attempt budget for the 5-failure burst
    let policy = net::RetryPolicy {
        max_attempts: 10,
        base_delay: Duration::from_millis(5),
        max_delay: Duration::from_millis(40),
        ..Default::default()
    };
    let spawn = |addr: &str| -> (Arc<Service>, net::Server) {
        let engine =
            Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&store_dir).unwrap());
        let svc = Arc::new(Service::daemon(engine));
        let server = net::Server::spawn(
            Arc::clone(&svc),
            addr,
            net::ServerConfig { workers: 2, queue_cap: 16, ..Default::default() },
        )
        .expect("binding the daemon");
        (svc, server)
    };

    // 2. daemon A takes the sweep half of the grid under fire
    let (_svc_a, server_a) = spawn("127.0.0.1:0");
    let addr = server_a.addr().to_string();
    let mut client = net::Client::new(&addr).with_retry(policy.clone());
    let sweep = client
        .request(&ServiceRequest::Sweep {
            benches: vec!["fw".to_string(), "hotspot".to_string()],
            depths: vec![1, 100],
            scale: Scale::Tiny,
            device: None,
        })
        .expect("the retry policy must ride out every injected fault");
    assert!(sweep.len() > 1, "head line + cells");
    let retries_a = client.retries();
    assert!(
        retries_a > 0,
        "dropped accept/read and truncated responses must have forced retries"
    );

    // 3. kill daemon A mid-grid; the store keeps an interrupted write
    server_a.shutdown();
    leave_interrupted_put_trace(&store_dir);

    // 4. daemon B: same address, same store — open heals the journal
    let (svc_b, server_b) = spawn(&addr);
    let mut client = net::Client::new(&addr).with_retry(policy);
    let items = client
        .request(&ServiceRequest::Run {
            experiments: exps.clone(),
            scale: Scale::Tiny,
            shard: None,
            device: None,
        })
        .expect("the restarted daemon must serve the full grid");
    let sink = service::cells_to_bench(&items, Scale::Tiny, &exps).unwrap();
    assert_eq!(
        sink, expect,
        "the faulted, killed-and-restarted grid must be byte-identical to the fault-free run"
    );

    let store = svc_b.engine().store().expect("daemon B is store-backed");
    assert!(
        store.journal_replays() > 0,
        "open must have healed the interrupted put_trace"
    );
    assert_eq!(store.journal_len(), 0, "no intent may leak past a clean run");
    assert!(!store.is_degraded(), "injected write faults must never degrade the store");
    assert!(
        retries_a + client.retries() > 0,
        "the soak is meaningless if nothing was retried"
    );
    assert!(fault::fired_total() > 0, "the plan must actually have fired");

    server_b.shutdown();
    let _ = std::fs::remove_dir_all(&base);
}

/// The eviction twin of [`leave_interrupted_put_trace`]: a daemon died
/// between writing the `evict` intent and deleting the doomed files.
/// Healing must finish the batch — re-delete every listed file — and
/// leave the journal empty.
fn leave_interrupted_evict(store_dir: &std::path::Path) {
    let key = "00000000000000bb";
    let intent = format!(
        "{{\"schema\": \"pipefwd-journal-v1\", \"op\": \"evict\", \
         \"key\": \"{key}\", \"files\": [\"entries/{key}.json\"]}}"
    );
    std::fs::write(store_dir.join("journal").join(format!("evict-{key}.json")), intent).unwrap();
    // the doomed entry is still on disk: the crash landed before its
    // remove_file, and the restarted open must carry it out
    std::fs::write(
        store_dir.join("entries").join(format!("{key}.json")),
        b"{\"schema\": \"pipefwd-store-v6\"}",
    )
    .unwrap();
}

/// Push everything a shard store holds to the daemon at `addr`. A
/// failed batch is retried whole: an injected `store.evict` fault
/// surfaces as an application-level error (a push reply must not claim
/// a budget it did not enforce), and re-importing is idempotent.
fn push_shard(addr: &str, policy: &net::RetryPolicy, shard_dir: &std::path::Path) {
    let records = Store::open_existing(shard_dir).unwrap().export_records();
    assert!(!records.is_empty(), "a shard run must leave records to push");
    let mut last_err = String::new();
    for _ in 0..6 {
        let mut client = net::Client::new(addr).with_retry(policy.clone());
        match client.request(&ServiceRequest::StorePush { records: records.clone() }) {
            Ok(items) => {
                assert!(!items.is_empty(), "a push reply carries its import report");
                return;
            }
            Err(e) => last_err = e,
        }
    }
    panic!("push never survived its injected faults: {last_err}");
}

/// The PR-10 fleet soak: resource governance under fire. Three shard
/// engines compute disjoint slices of the E4 grid on their own
/// unbudgeted stores, then push everything through `store_push` into a
/// central daemon whose budget is half the cold-store footprint — the
/// central store *must* evict to absorb the fleet — while the
/// all-sites schedule (now including `store.evict`) fires through the
/// exchange and the daemon is killed and restarted over the same store
/// mid-sequence with an interrupted eviction left on disk:
///
/// 1. fault-free reference run → expected sink bytes + cold footprint;
/// 2. three shard engines fill their own stores, fault-free;
/// 3. daemon A (budget = cold/2) absorbs shard 0 under fire —
///    `governed_bytes ≤ max_bytes` checked after the push;
/// 4. daemon A is killed holding an interrupted `evict` (intent on
///    disk, doomed entry not yet deleted);
/// 5. daemon B reopens the same store — open finishes the eviction —
///    and absorbs the remaining shards; half the cold bytes cannot
///    hold the whole fleet, so eviction fires for real, rides out its
///    injected fault, and the budget invariant holds after every push;
/// 6. the three *shard* stores — the fleet's durable truth, immune to
///    what the central store evicted — merge into a fresh store that
///    replays the grid byte-identical without one fresh simulation.
#[test]
fn fleet_soak_budgeted_push_evicts_heals_and_merges_byte_identical() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::clear();
    let _armed = Armed(guard);

    let base = soak_dir("fleet");

    // 1. the fault-free truth, and the cold footprint the budget halves
    let exps = vec![ExperimentId::E4];
    let cells = grid_for(&exps, Scale::Tiny);
    assert!(cells.len() >= 3, "the fleet split needs at least one cell per shard");
    let reference =
        Engine::new(DeviceConfig::pac_a10(), 1).with_store(Store::open(base.join("cold")).unwrap());
    let _ = reference.run_cells(&cells);
    let expect = reference.bench_json(Scale::Tiny, &exps);
    let cold_bytes = reference.store().unwrap().governed_bytes();
    let budget = cold_bytes / 2;
    assert!(budget > 0, "the reference run must populate its store");

    // 2. three shard engines on their own unbudgeted stores
    let shard_dirs: Vec<PathBuf> = (0..3).map(|i| base.join(format!("shard{i}"))).collect();
    let fleet = shard_dirs.len();
    let mut slices: Vec<Vec<_>> = vec![vec![]; fleet];
    for (i, cell) in cells.iter().enumerate() {
        slices[i % fleet].push(cell.clone());
    }
    for (dir, slice) in shard_dirs.iter().zip(&slices) {
        let shard = Engine::new(DeviceConfig::pac_a10(), 1).with_store(Store::open(dir).unwrap());
        let _ = shard.run_cells(slice);
    }

    // every site armed, bounded: the network sites chew on the
    // exchange, the store faults burn on its early reads and writes
    // (a garbled read is a skipped export record or a miss, a torn
    // write or a faulted eviction fails one push attempt — which is
    // why push_shard retries whole batches), and everything must
    // converge through all of it
    fault::install(
        FaultPlan::parse(
            "seed=4242;net.accept=always x1;net.read=always x1;net.write=always x1;\
             engine.panic=always x1;store.read=always x1;store.write=always x1;\
             store.evict=always x1",
        )
        .unwrap(),
    );

    let policy = net::RetryPolicy {
        max_attempts: 10,
        base_delay: Duration::from_millis(5),
        max_delay: Duration::from_millis(40),
        ..Default::default()
    };
    let central_dir = base.join("central");
    let spawn = |addr: &str| -> (Arc<Service>, net::Server) {
        let store = Store::open(&central_dir).unwrap().with_max_bytes(Some(budget));
        let engine = Engine::new(DeviceConfig::pac_a10(), 2).with_store(store);
        let svc = Arc::new(Service::daemon(engine));
        let server = net::Server::spawn(
            Arc::clone(&svc),
            addr,
            net::ServerConfig { workers: 2, queue_cap: 16, ..Default::default() },
        )
        .expect("binding the daemon");
        (svc, server)
    };

    // 3. daemon A absorbs the first shard under fire
    let (svc_a, server_a) = spawn("127.0.0.1:0");
    let addr = server_a.addr().to_string();
    push_shard(&addr, &policy, &shard_dirs[0]);
    let store_a = svc_a.engine().store().expect("daemon A is store-backed");
    assert!(
        store_a.governed_bytes() <= budget,
        "budget invariant after push 1: {} > {budget}",
        store_a.governed_bytes()
    );

    // 4. kill daemon A mid-eviction (intent written, files not deleted)
    server_a.shutdown();
    leave_interrupted_evict(&central_dir);

    // 5. daemon B: same address, same store — open finishes the batch
    let (svc_b, server_b) = spawn(&addr);
    let store_b = svc_b.engine().store().expect("daemon B is store-backed");
    assert!(store_b.journal_replays() > 0, "open must heal the interrupted eviction");
    for dir in &shard_dirs[1..] {
        push_shard(&addr, &policy, dir);
        assert!(
            store_b.governed_bytes() <= budget,
            "budget invariant after every push: {} > {budget}",
            store_b.governed_bytes()
        );
    }
    assert!(
        store_b.evictions() > 0,
        "half the cold footprint cannot absorb the fleet without evicting"
    );
    assert_eq!(store_b.journal_len(), 0, "no intent may leak past a clean exchange");
    assert!(!store_b.is_degraded(), "budget pressure must never degrade the store");
    assert!(fault::fired_total() > 0, "the plan must actually have fired");
    server_b.shutdown();

    // 6. merge the shard stores and replay the grid warm
    fault::clear();
    let merged = Store::open(base.join("merge")).unwrap();
    for dir in &shard_dirs {
        let records = Store::open_existing(dir).unwrap().export_records();
        let report = merged.import_records(&records).unwrap();
        assert_eq!(report.rejected, 0, "shard records are valid once the plan is gone");
    }
    let replay = Engine::new(DeviceConfig::pac_a10(), 1).with_store(merged);
    let _ = replay.run_cells(&cells);
    assert_eq!(replay.simulations(), 0, "the shard stores must answer the whole grid");
    assert_eq!(
        replay.bench_json(Scale::Tiny, &exps),
        expect,
        "the budgeted, faulted, restarted fleet must merge byte-identical"
    );

    let _ = std::fs::remove_dir_all(&base);
}
