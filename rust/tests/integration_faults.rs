//! PR-9 robustness: the deterministic fault-injection harness and the
//! recovery machinery it exists to prove. Fault state is process-global
//! (`util::fault`), so every test here serializes on one mutex and
//! disarms on exit — this binary is its own process, so arming a plan
//! here never leaks into the library's unit tests or the other
//! integration binaries.
//!
//! The centerpiece is the seeded soak: the full E4 grid driven through
//! `pipefwd serve` and the retrying `net::Client` while a bounded fault
//! schedule fires at every site — connections dropped at accept,
//! requests dropped mid-read, responses truncated mid-stream, an engine
//! worker panicking under claim, store reads garbled and store writes
//! torn — plus a daemon kill-and-restart on the same address and store
//! directory mid-grid. The acceptance bar: the reassembled sink is
//! byte-identical to a fault-free serial run, with nonzero `retries`
//! and `journal_replays` proving the failures actually happened and
//! were recovered, and zero `journal/` intents left on disk.

use pipefwd::coordinator::{grid_for, net, service, Engine, ExperimentId, Service, ServiceRequest, Store};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::util::fault::{self, FaultPlan};
use pipefwd::workloads::Scale;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// One plan at a time: `util::fault` is process-global state.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Holds the serialization lock and disarms the plan on drop, so a
/// failing test cannot leave a live schedule behind for the next one
/// (the lock recovers from poison for the same reason).
struct Armed(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for Armed {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn arm(spec: &str) -> Armed {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::install(FaultPlan::parse(spec).unwrap_or_else(|e| panic!("{e}")));
    Armed(guard)
}

/// The same plan replays the same verdict at every call index, and a
/// limited rule never fires past its cap — the property every soak
/// assertion leans on.
#[test]
fn same_plan_replays_the_same_schedule_and_respects_caps() {
    let spec = "seed=11;store.write=0.5x6";
    let _armed = arm(spec);
    let first: Vec<bool> = (0..64).map(|_| fault::fire("store.write")).collect();
    let fired = first.iter().filter(|b| **b).count();
    assert!(fired > 0, "a 50% rule over 64 calls must fire at least once");
    assert!(fired <= 6, "the x6 cap bounds total fires, got {fired}");
    assert_eq!(fault::fired_total(), fired as u64);

    // reinstall resets the stream: the verdict sequence is identical
    fault::install(FaultPlan::parse(spec).unwrap());
    let second: Vec<bool> = (0..64).map(|_| fault::fire("store.write")).collect();
    assert_eq!(first, second, "same plan, same schedule");

    // a different seed draws a different schedule
    fault::install(FaultPlan::parse("seed=12;store.write=0.5x6").unwrap());
    let third: Vec<bool> = (0..64).map(|_| fault::fire("store.write")).collect();
    assert_ne!(first, third, "the seed must select the schedule");
}

/// Each site draws from its own stream: interleaving calls at another
/// site must not perturb this site's verdict sequence. (Arming one
/// fault never changes which calls another fault hits.)
#[test]
fn sites_draw_from_independent_streams() {
    let spec = "seed=9;store.read=0.5;net.write=0.5";
    let _armed = arm(spec);
    let solo: Vec<bool> = (0..32).map(|_| fault::fire("store.read")).collect();

    fault::install(FaultPlan::parse(spec).unwrap());
    let interleaved: Vec<bool> = (0..32)
        .map(|_| {
            let v = fault::fire("store.read");
            let _ = fault::fire("net.write"); // burns net.write's stream only
            v
        })
        .collect();
    assert_eq!(solo, interleaved, "store.read's stream must ignore net.write draws");
}

/// `install_from` with an explicit spec (the `--fault-plan` path) arms
/// the process and honors the cap.
#[test]
fn install_from_explicit_spec_arms_and_caps() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::install_from(Some("seed=3;engine.panic=always x2")).unwrap();
    let _armed = Armed(guard);
    assert!(fault::active());
    assert!(fault::fire("engine.panic"));
    assert!(fault::fire("engine.panic"));
    assert!(!fault::fire("engine.panic"), "the x2 cap must exhaust");
    assert!(!fault::fire("store.write"), "unarmed sites never fire");
    assert_eq!(fault::fired_total(), 2);
}

/// An installed-but-empty plan is byte-for-byte free: same sink, same
/// counters, zero fires — the "effectively free when disabled" half of
/// the harness contract.
#[test]
fn empty_plan_leaves_sink_and_counters_identical() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::clear();
    let _armed = Armed(guard);

    let exps = vec![ExperimentId::E2];
    let cells = grid_for(&exps, Scale::Tiny);

    let plain = Engine::new(DeviceConfig::pac_a10(), 1);
    let _ = plain.run_cells(&cells);

    fault::install(FaultPlan::parse("seed=99").unwrap()); // no rules
    assert!(!fault::active(), "a rule-free plan must stay disarmed");
    let under_plan = Engine::new(DeviceConfig::pac_a10(), 1);
    let _ = under_plan.run_cells(&cells);

    assert_eq!(
        plain.bench_json(Scale::Tiny, &exps),
        under_plan.bench_json(Scale::Tiny, &exps),
        "an empty plan must not move a byte of the sink"
    );
    assert_eq!(plain.simulations(), under_plan.simulations());
    assert_eq!(fault::fired_total(), 0);
}

fn soak_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pipefwd-faults-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Reconstruct the exact on-disk state a daemon killed mid-`put_trace`
/// leaves behind: the `journal/` intent plus a torn trace document.
/// (An in-process test cannot genuinely die between two writes, so the
/// soak reproduces the crash artifact through the documented journal
/// format — `docs/RELIABILITY.md` — and lets the restarted store heal
/// it for real.)
fn leave_interrupted_put_trace(store_dir: &std::path::Path) {
    let key = "00000000000000aa";
    let intent = format!(
        "{{\"schema\": \"pipefwd-journal-v1\", \"op\": \"put_trace\", \
         \"key\": \"{key}\", \"files\": [\"traces/{key}.json\"]}}"
    );
    std::fs::write(
        store_dir.join("journal").join(format!("put_trace-{key}.json")),
        intent,
    )
    .unwrap();
    // a trace file cut mid-write: parses as nothing, must be discarded
    std::fs::write(
        store_dir.join("traces").join(format!("{key}.json")),
        b"{\"schema\": \"pipefwd-store-v6\", \"kind\": \"trace\"",
    )
    .unwrap();
}

/// The PR-9 acceptance soak. Every injection site fires under a seeded,
/// bounded schedule while the E4 grid flows through serve + Client,
/// with a daemon kill-and-restart (same port, same store) mid-grid:
///
/// 1. fault-free serial reference run → the expected sink bytes;
/// 2. daemon A, schedule armed: a sweep request survives a dropped
///    accept, a dropped read, two truncated responses, and a worker
///    panic — the client's retry policy eats all of them;
/// 3. daemon A is killed; the store is left holding an interrupted
///    `put_trace` (intent + torn trace), the crash the journal exists
///    for;
/// 4. daemon B binds the *same* address over the *same* store — open
///    heals the journal — and serves the full E4 grid.
///
/// The sink must be byte-identical to the reference, with
/// `retries > 0`, `journal_replays > 0`, and an empty journal at exit.
#[test]
fn seeded_soak_is_byte_identical_through_faults_and_restart() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    fault::clear();
    let _armed = Armed(guard);

    let base = soak_dir("soak");
    let store_dir = base.join("store");

    // 1. the fault-free truth, before any plan is armed
    let exps = vec![ExperimentId::E4];
    let cells = grid_for(&exps, Scale::Tiny);
    let reference = Engine::new(DeviceConfig::pac_a10(), 1);
    let _ = reference.run_cells(&cells);
    let expect = reference.bench_json(Scale::Tiny, &exps);

    // bounded `always` rules: exact fire counts, all burned early, so
    // the run is deterministic and guaranteed to finish armed-then-clean
    fault::install(
        FaultPlan::parse(
            "seed=2026;net.accept=always x1;net.read=always x1;net.write=always x2;\
             engine.panic=always x1;store.read=always x2;store.write=always x2",
        )
        .unwrap(),
    );

    // fast, deterministic backoff so the soak spends its time computing,
    // not sleeping; generous attempt budget for the 5-failure burst
    let policy = net::RetryPolicy {
        max_attempts: 10,
        base_delay: Duration::from_millis(5),
        max_delay: Duration::from_millis(40),
        ..Default::default()
    };
    let spawn = |addr: &str| -> (Arc<Service>, net::Server) {
        let engine =
            Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&store_dir).unwrap());
        let svc = Arc::new(Service::daemon(engine));
        let server = net::Server::spawn(
            Arc::clone(&svc),
            addr,
            net::ServerConfig { workers: 2, queue_cap: 16, ..Default::default() },
        )
        .expect("binding the daemon");
        (svc, server)
    };

    // 2. daemon A takes the sweep half of the grid under fire
    let (_svc_a, server_a) = spawn("127.0.0.1:0");
    let addr = server_a.addr().to_string();
    let mut client = net::Client::new(&addr).with_retry(policy.clone());
    let sweep = client
        .request(&ServiceRequest::Sweep {
            benches: vec!["fw".to_string(), "hotspot".to_string()],
            depths: vec![1, 100],
            scale: Scale::Tiny,
            device: None,
        })
        .expect("the retry policy must ride out every injected fault");
    assert!(sweep.len() > 1, "head line + cells");
    let retries_a = client.retries();
    assert!(
        retries_a > 0,
        "dropped accept/read and truncated responses must have forced retries"
    );

    // 3. kill daemon A mid-grid; the store keeps an interrupted write
    server_a.shutdown();
    leave_interrupted_put_trace(&store_dir);

    // 4. daemon B: same address, same store — open heals the journal
    let (svc_b, server_b) = spawn(&addr);
    let mut client = net::Client::new(&addr).with_retry(policy);
    let items = client
        .request(&ServiceRequest::Run {
            experiments: exps.clone(),
            scale: Scale::Tiny,
            shard: None,
            device: None,
        })
        .expect("the restarted daemon must serve the full grid");
    let sink = service::cells_to_bench(&items, Scale::Tiny, &exps).unwrap();
    assert_eq!(
        sink, expect,
        "the faulted, killed-and-restarted grid must be byte-identical to the fault-free run"
    );

    let store = svc_b.engine().store().expect("daemon B is store-backed");
    assert!(
        store.journal_replays() > 0,
        "open must have healed the interrupted put_trace"
    );
    assert_eq!(store.journal_len(), 0, "no intent may leak past a clean run");
    assert!(!store.is_degraded(), "injected write faults must never degrade the store");
    assert!(
        retries_a + client.retries() > 0,
        "the soak is meaningless if nothing was retried"
    );
    assert!(fault::fired_total() > 0, "the plan must actually have fired");

    server_b.shutdown();
    let _ = std::fs::remove_dir_all(&base);
}
