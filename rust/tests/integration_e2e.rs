//! End-to-end: the full evaluation pipeline at Tiny scale produces every
//! table with the paper's qualitative content. (The Small-scale numbers
//! live in EXPERIMENTS.md and the benches.)

use pipefwd::coordinator;
use pipefwd::sim::device::DeviceConfig;
use pipefwd::workloads::Scale;

#[test]
fn table2_has_all_rows_and_sane_cells() {
    let cfg = DeviceConfig::pac_a10();
    let t = coordinator::table2(Scale::Tiny, &cfg);
    assert_eq!(t.rows.len(), 10);
    for row in &t.rows {
        let speedup: f64 = row[2].parse().unwrap();
        assert!(speedup > 0.3 && speedup < 500.0, "{row:?}");
        let logic: f64 = row[3].parse().unwrap();
        assert!(logic > 14.0 && logic < 60.0, "{row:?}");
        let brams: u32 = row[5].parse().unwrap();
        assert!(brams >= 380 && brams < 1500, "{row:?}");
    }
}

#[test]
fn figure4_average_gain_in_paper_band() {
    let cfg = DeviceConfig::pac_a10();
    let t = coordinator::figure4(Scale::Tiny, &cfg);
    let avg_row = t.rows.last().unwrap();
    let avg: f64 = avg_row[1].parse().unwrap();
    // paper: +39% average; we accept a generous band at Tiny scale
    assert!(avg > 1.1 && avg < 2.2, "avg M2C2 gain {avg}");
}

#[test]
fn table3_regular_benefits_more_than_irregular() {
    let cfg = DeviceConfig::pac_a10();
    let t = coordinator::table3(Scale::Tiny, &cfg);
    assert_eq!(t.rows.len(), 4);
    let s = |r: usize| -> f64 { t.rows[r][2].trim_end_matches('x').parse().unwrap() };
    // M_AI10_R gains more than M_AI10_IR (paper: 1.55 vs 1.00)
    assert!(s(0) > s(1), "R {} vs IR {}", s(0), s(1));
    // the divergent/DLCD set gains (paper: 1.90 / 1.84)
    assert!(s(2) > 1.2 && s(3) > 1.2);
}

#[test]
fn intext_metrics_match_paper_structure() {
    let cfg = DeviceConfig::pac_a10();
    let t = coordinator::intext(Scale::Tiny, &cfg);
    // fw row: II 285 -> 1
    let fw = t.rows.iter().find(|r| r[0] == "fw").unwrap();
    assert_eq!(fw[1], "285");
    assert_eq!(fw[2], "1");
    // backprop row: baseline II in the 400s
    let bp = t.rows.iter().find(|r| r[0] == "backprop").unwrap();
    let ii: u32 = bp[1].parse().unwrap();
    assert!((380..=470).contains(&ii));
    // bandwidth rises for the serialized benchmarks
    for name in ["fw", "mis", "backprop"] {
        let row = t.rows.iter().find(|r| r[0] == name).unwrap();
        let b_bw: f64 = row[3].parse().unwrap();
        let f_bw: f64 = row[4].parse().unwrap();
        assert!(f_bw > b_bw, "{name}: FF bandwidth should rise ({b_bw} -> {f_bw})");
    }
}

#[test]
fn headline_claims_reproduce_at_tiny() {
    let cfg = DeviceConfig::pac_a10();
    let h = coordinator::headline(Scale::Tiny, &cfg);
    assert!(h.max_ff_speedup > 20.0, "max ff {:.1}", h.max_ff_speedup);
    assert!(h.avg_ff_speedup_gainers > 5.0, "avg {:.1}", h.avg_ff_speedup_gainers);
    assert!(h.max_total_speedup >= h.max_ff_speedup * 0.9);
}

#[test]
fn csv_export_roundtrip() {
    let cfg = DeviceConfig::pac_a10();
    let t = coordinator::table1(Scale::Tiny);
    let csv = t.to_csv();
    assert!(csv.lines().count() == 11); // header + 10 benchmarks
    let _ = cfg;
}
