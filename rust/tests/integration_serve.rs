//! PR-6 serve integration: the measurement daemon must (a) answer N
//! concurrent clients requesting overlapping grids at the cost of ONE
//! cold grid — the engine's claim/fulfil memo is the dedup layer, the
//! transport adds nothing — with every client's reassembled sink
//! byte-identical to the serial CLI path; (b) survive malformed,
//! truncated, and oversized requests without losing the accept loop;
//! (c) treat a mid-stream client disconnect as a failed response write,
//! not an abandoned claim; and (d) exchange store records faithfully
//! over the wire.

use pipefwd::coordinator::{
    grid_for, net, service, Cell, Engine, ExperimentId, Service, ServiceRequest, Store,
};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::Scale;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;

fn spawn_daemon(engine: Engine, workers: usize) -> (Arc<Service>, net::Server) {
    let svc = Arc::new(Service::daemon(engine));
    let server = net::Server::spawn(
        Arc::clone(&svc),
        "127.0.0.1:0",
        net::ServerConfig { workers, queue_cap: 16, ..Default::default() },
    )
    .expect("binding a loopback port");
    (svc, server)
}

/// One raw HTTP exchange: write the payload verbatim, half-close, read
/// the response to EOF. This is how the wire-abuse tests speak to the
/// daemon without the client layer's well-formedness guarantees.
fn raw_exchange(addr: &str, payload: &[u8]) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(payload).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn http_status(response: &str) -> u16 {
    response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("no HTTP status in response: {response:?}"))
}

/// Acceptance: three concurrent clients requesting the same E2 grid cost
/// the server exactly one cold grid (same `simulations`/`trace_runs` as
/// one serial reference run), and every client's sink is byte-identical
/// to the serial `bench_json`.
#[test]
fn three_concurrent_clients_cost_one_cold_grid() {
    let exps = vec![ExperimentId::E2];
    let (svc, server) = spawn_daemon(Engine::new(DeviceConfig::pac_a10(), 2), 4);
    let addr = server.addr().to_string();

    let handles: Vec<_> = (0..3)
        .map(|_| {
            let addr = addr.clone();
            let exps = exps.clone();
            std::thread::spawn(move || {
                net::request(
                    &addr,
                    &ServiceRequest::Run {
                        experiments: exps,
                        scale: Scale::Tiny,
                        shard: None,
                        device: None,
                    },
                )
                .expect("daemon run request")
            })
        })
        .collect();
    let responses: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // one cold serial run of the same grid is the cost ceiling
    let reference = Engine::new(DeviceConfig::pac_a10(), 1);
    let _ = reference.run_cells(&grid_for(&exps, Scale::Tiny));

    assert_eq!(
        svc.engine().simulations(),
        reference.simulations(),
        "N overlapping clients must cost one cold grid, not N"
    );
    assert_eq!(svc.engine().trace_runs(), reference.trace_runs());
    assert_eq!(svc.clients_served(), 3);

    let expect = reference.bench_json(Scale::Tiny, &exps);
    for items in &responses {
        assert_eq!(
            service::cells_to_bench(items, Scale::Tiny, &exps).unwrap(),
            expect,
            "every client's reassembled sink must match the serial path byte-for-byte"
        );
    }

    // the live stats endpoint reflects the same counters
    let stats = net::get_stats(&addr).unwrap();
    assert_eq!(stats.get("schema").and_then(|s| s.as_str()), Some("pipefwd-api-v1"));
    let counters = stats.get("counters").expect("stats counters");
    assert_eq!(
        counters.get("schema").and_then(|s| s.as_str()),
        Some("pipefwd-counters-v3")
    );
    assert_eq!(
        counters.get("simulations").and_then(|v| v.as_f64()),
        Some(reference.simulations() as f64)
    );
    // the stats GET itself is the 4th connection
    assert_eq!(counters.get("clients_served").and_then(|v| v.as_f64()), Some(4.0));

    server.shutdown();
}

/// The daemon's sweep answers are byte-identical to the serial sweep.
#[test]
fn daemon_sweep_matches_serial_sink_bytes() {
    let (_svc, server) = spawn_daemon(Engine::new(DeviceConfig::pac_a10(), 2), 2);
    let addr = server.addr().to_string();

    let benches = vec!["fw".to_string(), "hotspot".to_string()];
    let depths = vec![1usize, 100];
    let items = net::request(
        &addr,
        &ServiceRequest::Sweep {
            benches: benches.clone(),
            depths: depths.clone(),
            scale: Scale::Tiny,
            device: None,
        },
    )
    .unwrap();
    let bench = service::cells_to_bench(&items, Scale::Tiny, &[]).unwrap();

    let reference = Engine::new(DeviceConfig::pac_a10(), 1);
    let cells: Vec<Cell> = benches
        .iter()
        .flat_map(|b| {
            depths
                .iter()
                .map(|d| Cell::new(b, Variant::FeedForward { depth: *d }, Scale::Tiny))
                .collect::<Vec<_>>()
        })
        .collect();
    let _ = reference.run_cells(&cells);
    assert_eq!(bench, reference.bench_json(Scale::Tiny, &[]));

    server.shutdown();
}

/// Wire abuse: malformed heads, missing/oversized/truncated bodies, bad
/// JSON, and wrong schemas are each rejected with a structured error —
/// and the accept loop survives all of them, proven by a well-formed
/// request afterwards.
#[test]
fn malformed_requests_are_rejected_without_killing_the_accept_loop() {
    let (svc, server) = spawn_daemon(Engine::new(DeviceConfig::pac_a10(), 1), 2);
    let addr = server.addr().to_string();

    // not HTTP at all
    let r = raw_exchange(&addr, b"GARBAGE\r\n\r\n");
    assert_eq!(http_status(&r), 405, "unknown method: {r:?}");

    // unknown path
    let r = raw_exchange(&addr, b"GET /nope HTTP/1.1\r\n\r\n");
    assert_eq!(http_status(&r), 404);
    assert!(r.contains("unknown path"), "{r:?}");

    // POST without Content-Length
    let r = raw_exchange(&addr, b"POST /api/v1 HTTP/1.1\r\n\r\n");
    assert_eq!(http_status(&r), 411);

    // oversized body, rejected before allocation
    let r = raw_exchange(
        &addr,
        b"POST /api/v1 HTTP/1.1\r\nContent-Length: 999999999999\r\n\r\n",
    );
    assert_eq!(http_status(&r), 413);
    assert!(r.contains("exceeds"), "{r:?}");

    // truncated body: promises 100 bytes, delivers 2
    let r = raw_exchange(&addr, b"POST /api/v1 HTTP/1.1\r\nContent-Length: 100\r\n\r\n{}");
    assert_eq!(http_status(&r), 400);
    assert!(r.contains("truncated body"), "{r:?}");

    // body that is not JSON
    let r = raw_exchange(&addr, b"POST /api/v1 HTTP/1.1\r\nContent-Length: 8\r\n\r\nnot json");
    assert_eq!(http_status(&r), 400);

    // valid JSON, wrong schema
    let body = br#"{"schema": "pipefwd-api-v0", "type": "stats"}"#;
    let head = format!("POST /api/v1 HTTP/1.1\r\nContent-Length: {}\r\n\r\n", body.len());
    let mut payload = head.into_bytes();
    payload.extend_from_slice(body);
    let r = raw_exchange(&addr, &payload);
    assert_eq!(http_status(&r), 400);
    assert!(r.contains("unsupported schema"), "{r:?}");

    // the daemon is still alive and serving
    let items = net::request(
        &addr,
        &ServiceRequest::Measure {
            workload: "fw".into(),
            variant: Variant::FeedForward { depth: 1 },
            scale: Scale::Tiny,
            device: None,
        },
    )
    .expect("daemon must survive wire abuse");
    assert_eq!(items.len(), 2, "head line + one cell");
    assert_eq!(svc.engine().simulations(), 1);

    server.shutdown();
}

/// A client that sends a valid request and vanishes without reading the
/// response must not poison the claim: the worker computes to completion
/// and fulfils the memo, so the next client asking for the same cell
/// costs zero additional simulations.
#[test]
fn mid_stream_disconnect_does_not_abandon_the_claim() {
    let (svc, server) = spawn_daemon(Engine::new(DeviceConfig::pac_a10(), 2), 2);
    let addr = server.addr().to_string();

    let req = ServiceRequest::Measure {
        workload: "fw".into(),
        variant: Variant::FeedForward { depth: 1 },
        scale: Scale::Tiny,
        device: None,
    };
    let body = service::encode_request(&req).to_compact();
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let head = format!(
            "POST /api/v1 HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        s.write_all(head.as_bytes()).unwrap();
        s.write_all(body.as_bytes()).unwrap();
        // vanish without reading a byte of the response
    }

    let items = net::request(&addr, &req).expect("second client");
    assert_eq!(items.len(), 2);
    assert_eq!(
        items[1].get("status").and_then(|s| s.as_str()),
        Some("ok"),
        "the surviving client gets the real measurement"
    );
    assert_eq!(
        svc.engine().simulations(),
        1,
        "whichever request computed, the other was fulfilled from its claim"
    );

    server.shutdown();
}

/// Store exchange over the wire: a store-backed daemon's `store_pull`
/// records import cleanly into a fresh local store, and `store_push`
/// travels the other way.
#[test]
fn store_records_roundtrip_between_daemon_and_client() {
    let base = std::env::temp_dir().join(format!("pipefwd-serve-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    let server_dir: PathBuf = base.join("server");
    let client_dir: PathBuf = base.join("client");

    let engine = Engine::new(DeviceConfig::pac_a10(), 1)
        .with_store(Store::open(&server_dir).unwrap());
    let (svc, server) = spawn_daemon(engine, 2);
    let addr = server.addr().to_string();

    // populate the daemon's store with one measured cell
    let req = ServiceRequest::Measure {
        workload: "fw".into(),
        variant: Variant::FeedForward { depth: 1 },
        scale: Scale::Tiny,
        device: None,
    };
    net::request(&addr, &req).unwrap();

    // pull: every tier record arrives typed and imports cleanly
    let items = net::request(&addr, &ServiceRequest::StorePull).unwrap();
    assert!(!items.is_empty(), "a measured cell must export records");
    let records: Vec<_> = items
        .iter()
        .map(|l| service::decode_record(l).unwrap())
        .collect();
    let local = Store::open(&client_dir).unwrap();
    let report = local.import_records(&records).unwrap();
    assert_eq!(report.imported, records.len());
    assert_eq!(report.rejected, 0);
    // a warm engine over the pulled store answers without simulating
    let warm = Engine::new(DeviceConfig::pac_a10(), 1)
        .with_store(Store::open_existing(&client_dir).unwrap());
    let w = pipefwd::coordinator::resolve_workload("fw").unwrap();
    warm.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny).unwrap();
    assert_eq!(warm.simulations(), 0, "pulled records must answer a warm run");

    // push: the same records go back up (all duplicates → zero imported,
    // and the daemon's store is unchanged)
    let before = svc.engine().store().unwrap().export_records().len();
    let items = net::request(&addr, &ServiceRequest::StorePush { records }).unwrap();
    assert_eq!(items[0].get("count").and_then(|v| v.as_usize()), Some(0));
    assert_eq!(items[0].get("rejected").and_then(|v| v.as_usize()), Some(0));
    // the daemon already answered this cell itself, so no claim was
    // outstanding for the pushed result to fulfil
    assert_eq!(items[0].get("fulfilled").and_then(|v| v.as_usize()), Some(0));
    assert_eq!(svc.engine().store().unwrap().export_records().len(), before);

    server.shutdown();
    let _ = std::fs::remove_dir_all(&base);
}
