//! PR-7 device-zoo integration: the calibrated registry profiles must
//! (1) all resolve by name, (2) disagree about the best channel depth
//! (the portability claim the E8 grid exists to show), (3) share one
//! store's device-free trace tier so a `--device all` style sweep pays
//! the functional interpreter once, (4) keep reading pre-zoo (schema v4)
//! `arria10` records as hits after the v5 bump, and (5) pin every
//! device's modelled cycle counts to the committed fixture.

use pipefwd::coordinator::{cross_device_table, resolve_workload, Engine, Store};
use pipefwd::coordinator::store::{STORE_SCHEMA, STORE_SCHEMA_COMPAT};
use pipefwd::sim::device::{by_name, DeviceConfig, DeviceRegistry, DEVICE_NAMES};
use pipefwd::transform::Variant;
use pipefwd::util::json::{self, Json};
use pipefwd::workloads::Scale;
use std::path::{Path, PathBuf};

const TRIO: [&str; 3] = ["fw", "hotspot", "mis"];

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pipefwd-device-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Every documented name resolves, carries itself as `cfg.name`, and the
/// registry iterates in presentation order with `arria10` first (the
/// default everywhere a device is optional).
#[test]
fn registry_resolves_every_documented_name() {
    assert_eq!(DEVICE_NAMES.len(), 4);
    for name in DEVICE_NAMES {
        let cfg = by_name(name).unwrap_or_else(|| panic!("registry name `{name}` must resolve"));
        assert_eq!(cfg.name, name);
    }
    let all = DeviceRegistry::all();
    assert_eq!(all.len(), DEVICE_NAMES.len());
    assert_eq!(all[0].name, "arria10");
    assert!(by_name("all").is_none(), "`all` is CLI fan-out sugar, not a device");
}

/// The acceptance claim behind the whole zoo: at least one workload's
/// best pipe depth differs across devices. On `arria10` the channel-fill
/// cost is zero, every depth ties, and the strict-`<` sweep keeps depth
/// 1; on `stratix10-hbm` deep channels amortise the 24-cycle fill and
/// the deepest depth wins.
#[test]
fn best_depth_disagrees_across_the_registry() {
    let a10 = Engine::new(DeviceConfig::pac_a10(), 2);
    let hbm = Engine::new(DeviceConfig::stratix10_hbm(), 2);
    let w = resolve_workload("fw").unwrap();
    let a = a10.best_ff(w.as_ref(), Scale::Tiny).unwrap();
    let h = hbm.best_ff(w.as_ref(), Scale::Tiny).unwrap();
    assert_eq!(a.variant, "ff(d1)", "zero fill cost: all depths tie, depth 1 kept");
    assert_eq!(h.variant, "ff(d1000)", "24-cycle fill: the deepest depth strictly wins");

    // ... and the stitched `--device all` table shows it: one row per
    // (benchmark, device), fw's two rows naming different best variants
    let engines = [&a10, &hbm];
    let t = cross_device_table(&engines, Scale::Tiny);
    assert_eq!(t.rows.len(), TRIO.len() * engines.len());
    let fw: Vec<&Vec<String>> = t.rows.iter().filter(|r| r[0] == "fw").collect();
    assert_eq!(fw.len(), 2);
    assert_eq!(fw[0][1], "arria10");
    assert_eq!(fw[1][1], "stratix10-hbm");
    assert_ne!(fw[0][3], fw[1][3], "the best-FF column is where portability breaks");
}

/// A `--device all` sweep through one shared store directory pays the
/// functional interpreter only for the first device: trace keys are
/// device-free, so every later engine answers its trace lookups from the
/// store and only replays the per-device performance model.
#[test]
fn cross_device_sweep_pays_the_interpreter_once() {
    let dir = tmp_dir("all-sweep");
    for (i, cfg) in DeviceRegistry::all().into_iter().enumerate() {
        let e = Engine::new(cfg, 2).with_store(Store::open(&dir).unwrap());
        for name in TRIO {
            let w = resolve_workload(name).unwrap();
            e.measure(w.as_ref(), Variant::Baseline, Scale::Tiny).unwrap();
            e.best_ff(w.as_ref(), Scale::Tiny).unwrap();
        }
        if i == 0 {
            assert!(e.trace_runs() > 0, "the first device must run the interpreter");
        } else {
            assert_eq!(
                e.trace_runs(),
                0,
                "device #{i} must replay the shared device-free traces, not re-interpret"
            );
            assert!(e.simulations() > 0, "the per-device model replay is real work");
        }
        e.store().unwrap().write_manifest().unwrap();
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Rewrite every non-manifest store record from the v5 schema string to
/// the v4 one, mimicking a store written before the device zoo existed
/// (`arria10` content keys are unchanged by design).
fn downgrade_records(dir: &Path) -> usize {
    let mut n = 0;
    for entry in std::fs::read_dir(dir).unwrap() {
        let p = entry.unwrap().path();
        if p.is_dir() {
            n += downgrade_records(&p);
            continue;
        }
        if p.file_name().and_then(|s| s.to_str()) == Some("MANIFEST.json") {
            continue;
        }
        let Ok(text) = std::fs::read_to_string(&p) else { continue };
        if text.contains(STORE_SCHEMA) {
            std::fs::write(&p, text.replace(STORE_SCHEMA, STORE_SCHEMA_COMPAT)).unwrap();
            n += 1;
        }
    }
    n
}

/// Store compatibility across the v5 bump: records written under the v4
/// schema (pre-device-zoo, necessarily `arria10`) must replay as warm
/// hits — zero simulations, zero interpreter runs — because `arria10`
/// deliberately hashes to the same content keys as before the zoo.
#[test]
fn pre_zoo_arria10_records_hit_after_schema_bump() {
    let dir = tmp_dir("v4-compat");
    let cold = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let w = resolve_workload("fw").unwrap();
    let cold_m = cold.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny).unwrap();
    assert!(cold.simulations() > 0);
    cold.store().unwrap().write_manifest().unwrap();

    assert!(downgrade_records(&dir) > 0, "the cold run must have persisted v5 records");

    let warm = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let warm_m = warm.measure(w.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny).unwrap();
    assert_eq!(warm.simulations(), 0, "v4 records must answer a v5 engine's lookups");
    assert_eq!(warm.trace_runs(), 0);
    assert!(warm.store_hits() > 0);
    assert_eq!(warm_m.seconds, cold_m.seconds);
    assert_eq!(warm_m.cycles, cold_m.cycles);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Golden per-device numbers, pinned to `tests/fixtures/device_cycles.json`.
///
/// The fixture self-blesses: committed with `"blessed": false`, the first
/// `cargo test` run fills in the modelled cycle counts and flips the
/// flag; every later run compares strictly. Re-bless after an intentional
/// model change by resetting the file to `"blessed": false`.
#[test]
fn golden_cycles_match_the_committed_fixture() {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/device_cycles.json");
    let text = std::fs::read_to_string(&path).expect("committed fixture must exist");
    let committed = json::parse(&text).expect("fixture must parse");
    assert_eq!(committed.get("schema").unwrap().as_str(), Some("pipefwd-device-fixture-v1"));
    let blessed = committed.get("blessed").unwrap().as_bool().unwrap();

    let mut devices: Vec<(String, Json)> = vec![];
    for cfg in DeviceRegistry::all() {
        let name = cfg.name;
        let e = Engine::new(cfg, 2);
        let mut rows: Vec<(String, Json)> = vec![];
        for bench in TRIO {
            let w = resolve_workload(bench).unwrap();
            let base = e.measure(w.as_ref(), Variant::Baseline, Scale::Tiny).unwrap();
            let ff = e.best_ff(w.as_ref(), Scale::Tiny).unwrap();
            rows.push((
                bench.to_string(),
                Json::Obj(vec![
                    ("baseline_cycles".into(), Json::Num(base.cycles)),
                    ("best_variant".into(), Json::Str(ff.variant.clone())),
                    ("ff_cycles".into(), Json::Num(ff.cycles)),
                ]),
            ));
        }
        devices.push((name.to_string(), Json::Obj(rows)));
    }
    let current = Json::Obj(vec![
        ("schema".into(), Json::Str("pipefwd-device-fixture-v1".into())),
        ("blessed".into(), Json::Bool(true)),
        ("scale".into(), Json::Str("tiny".into())),
        ("devices".into(), Json::Obj(devices)),
    ]);

    if !blessed {
        std::fs::write(&path, current.to_pretty()).expect("blessing the fixture");
        eprintln!("blessed {} — reruns now compare against these numbers", path.display());
        return;
    }
    assert_eq!(
        committed.to_pretty(),
        current.to_pretty(),
        "per-device modelled cycles drifted from the blessed fixture — if the model \
         change is intentional, reset the fixture to `\"blessed\": false` and rerun"
    );
}
