//! Property tests over the transformation pipeline: for randomly generated
//! feed-forward-eligible kernels, every design variant must compute exactly
//! the same outputs as the single work-item baseline, pipes must conserve
//! tokens, and the compiler model must see the expected structure.

use pipefwd::ir::{validate_program, Program};
use pipefwd::sim::exec::{run_group, ExecOptions};
use pipefwd::transform::{apply_variant, name_loads, Variant};
use pipefwd::util::testing::{check, gen_kernel};

fn outputs(img: &pipefwd::sim::mem::MemoryImage) -> (Vec<f32>, Vec<f32>) {
    (
        img.buf("out").unwrap().to_f32s(),
        img.buf("out2").unwrap().to_f32s(),
    )
}

#[test]
fn all_variants_preserve_semantics() {
    check("variants_preserve_semantics", 60, |rng| {
        let g = gen_kernel(rng);
        let base_img = g.image();
        run_group(&Program::single(g.kernel.clone()), &base_img, &ExecOptions::default())
            .map_err(|e| e.to_string())?;
        let want = outputs(&base_img);

        for variant in [
            Variant::FeedForward { depth: 1 },
            Variant::FeedForward { depth: 100 },
            Variant::MxCx { parts: 2, depth: 1 },
            Variant::MxCx { parts: 3, depth: 4 },
            Variant::M1Cx { consumers: 2, depth: 1 },
        ] {
            let prog = apply_variant(&g.kernel, variant).map_err(|e| e.to_string())?;
            validate_program(&prog).map_err(|e| e.to_string())?;
            let img = g.image();
            run_group(&prog, &img, &ExecOptions::default()).map_err(|e| e.to_string())?;
            let got = outputs(&img);
            if got != want {
                return Err(format!("variant {variant:?} diverged from baseline"));
            }
        }
        Ok(())
    });
}

#[test]
fn pipes_conserve_tokens() {
    check("pipes_conserve_tokens", 40, |rng| {
        let g = gen_kernel(rng);
        let prog = apply_variant(&g.kernel, Variant::FeedForward { depth: 1 })
            .map_err(|e| e.to_string())?;
        let img = g.image();
        let run = run_group(&prog, &img, &ExecOptions::default()).map_err(|e| e.to_string())?;
        let writes: u64 = run.profiles.iter().map(|p| p.pipe_writes).sum();
        let reads: u64 = run.profiles.iter().map(|p| p.pipe_reads).sum();
        if writes != reads {
            return Err(format!("token mismatch: {writes} writes vs {reads} reads"));
        }
        // every dynamic load in the memory kernel produced one token
        let mem_loads: u64 = run.profiles[0].sites.iter().map(|s| s.count).sum::<u64>();
        if writes != mem_loads {
            return Err(format!("{writes} tokens for {mem_loads} loads"));
        }
        Ok(())
    });
}

#[test]
fn memory_kernel_is_load_only_compute_is_store_only() {
    check("split_roles", 40, |rng| {
        let g = gen_kernel(rng);
        let prog = apply_variant(&g.kernel, Variant::FeedForward { depth: 1 })
            .map_err(|e| e.to_string())?;
        let mem = &prog.kernels[0];
        let cmp = &prog.kernels[1];
        if mem.store_count() != 0 {
            return Err("memory kernel contains stores".into());
        }
        if cmp.load_count() != 0 {
            return Err("compute kernel contains global loads".into());
        }
        // every load of the normalized baseline survives in the memory kernel
        let named = name_loads(&g.kernel);
        if mem.load_count() != named.load_count() {
            return Err(format!(
                "memory kernel has {} loads, baseline {}",
                mem.load_count(),
                named.load_count()
            ));
        }
        Ok(())
    });
}

#[test]
fn dce_and_simplify_preserve_semantics() {
    check("cleanup_preserves_semantics", 40, |rng| {
        let g = gen_kernel(rng);
        let base_img = g.image();
        run_group(&Program::single(g.kernel.clone()), &base_img, &ExecOptions::default())
            .map_err(|e| e.to_string())?;
        let want = outputs(&base_img);

        let cleaned = pipefwd::transform::simplify_kernel(&pipefwd::transform::dce_kernel(
            &name_loads(&g.kernel),
        ));
        let img = g.image();
        run_group(&Program::single(cleaned), &img, &ExecOptions::default())
            .map_err(|e| e.to_string())?;
        if outputs(&img) != want {
            return Err("dce/simplify changed results".into());
        }
        Ok(())
    });
}

#[test]
fn vectorize_preserves_semantics_when_trip_divides() {
    check("vectorize_preserves_semantics", 30, |rng| {
        let g = gen_kernel(rng); // n is a multiple of 16
        for w in [2usize, 4] {
            let vk = pipefwd::transform::vectorize(&g.kernel, w);
            pipefwd::ir::validate_kernel(&vk).map_err(|e| e.to_string())?;
            let base_img = g.image();
            run_group(&Program::single(g.kernel.clone()), &base_img, &ExecOptions::default())
                .map_err(|e| e.to_string())?;
            let img = g.image();
            run_group(&Program::single(vk), &img, &ExecOptions::default())
                .map_err(|e| e.to_string())?;
            if outputs(&img) != outputs(&base_img) {
                return Err(format!("vectorize({w}) changed results"));
            }
        }
        Ok(())
    });
}
