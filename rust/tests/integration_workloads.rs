//! Cross-workload integration: every benchmark validates under every
//! applicable variant at Tiny scale, and the Table-2 *shape* holds — who
//! wins, who stays flat (the reproduction's core claim, E1).

use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::{run_workload, suite, Scale};

#[test]
fn all_benchmarks_validate_under_all_variants_tiny() {
    let cfg = DeviceConfig::pac_a10();
    for w in suite() {
        for variant in [Variant::Baseline, Variant::FeedForward { depth: 1 }] {
            run_workload(w.as_ref(), variant, Scale::Tiny, &cfg)
                .unwrap_or_else(|e| panic!("{} {variant:?}: {e}", w.name()));
        }
        if w.supports_replication() {
            run_workload(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny, &cfg)
                .unwrap_or_else(|e| panic!("{} m2c2: {e}", w.name()));
        }
    }
}

/// The paper's Table-2 sign structure at Tiny scale: serialized-baseline
/// benchmarks gain a lot; already-pipelined ones sit near 1x.
#[test]
fn table2_shape_holds_at_tiny() {
    let cfg = DeviceConfig::pac_a10();
    let speedup = |name: &str| -> f64 {
        let w = pipefwd::workloads::by_name(name).unwrap();
        let b = run_workload(w.as_ref(), Variant::Baseline, Scale::Tiny, &cfg).unwrap();
        let f = run_workload(w.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg)
            .unwrap();
        b.metrics.seconds / f.metrics.seconds
    };
    // big gainers (paper: 13.8x, 65x, 44.5x, 51x, 6.5x)
    assert!(speedup("bfs") > 3.0);
    assert!(speedup("fw") > 20.0);
    assert!(speedup("backprop") > 10.0);
    assert!(speedup("nw") > 10.0);
    assert!(speedup("mis") > 2.0);
    // flats (paper: 0.85x, 0.88x, 1.02x, 0.96x)
    let flat = |n: &str| {
        let s = speedup(n);
        assert!(s > 0.55 && s < 1.5, "{n} expected flat, got {s}");
    };
    flat("hotspot");
    flat("hotspot3d");
    flat("color");
    flat("pagerank");
}

/// Depth-insensitivity (E4c) on a real benchmark at Tiny scale.
#[test]
fn channel_depth_is_insignificant_for_fw() {
    let cfg = DeviceConfig::pac_a10();
    let w = pipefwd::workloads::by_name("fw").unwrap();
    let mut times = vec![];
    for depth in [1usize, 100, 1000] {
        let h = run_workload(w.as_ref(), Variant::FeedForward { depth }, Scale::Tiny, &cfg)
            .unwrap();
        times.push(h.metrics.seconds);
    }
    let max = times.iter().cloned().fold(0.0, f64::max);
    let min = times.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max / min < 1.1, "depth sweep spread: {times:?}");
}

/// M1C2 is not better than M2C2 (paper §3: separate producers win).
#[test]
fn shared_producer_not_better() {
    let cfg = DeviceConfig::pac_a10();
    for name in ["fw", "mis"] {
        let w = pipefwd::workloads::by_name(name).unwrap();
        let m2 =
            run_workload(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny, &cfg)
                .unwrap();
        let m1 =
            run_workload(w.as_ref(), Variant::M1Cx { consumers: 2, depth: 1 }, Scale::Tiny, &cfg)
                .unwrap();
        assert!(
            m1.metrics.seconds >= m2.metrics.seconds * 0.95,
            "{name}: m1c2 ({}) beat m2c2 ({})",
            m1.metrics.seconds,
            m2.metrics.seconds
        );
    }
}

/// Area model deltas (E1): feed-forward costs a little logic; M2C2 costs
/// noticeably more (the paper's +31% average logic overhead).
#[test]
fn area_overheads_ordered() {
    let cfg = DeviceConfig::pac_a10();
    let w = pipefwd::workloads::by_name("fw").unwrap();
    let b = run_workload(w.as_ref(), Variant::Baseline, Scale::Tiny, &cfg).unwrap();
    let f = run_workload(w.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg).unwrap();
    let m = run_workload(w.as_ref(), Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny, &cfg)
        .unwrap();
    assert!(f.area.logic_frac >= b.area.logic_frac * 0.98);
    assert!(m.area.logic_frac > f.area.logic_frac * 1.1);
}

/// Vectorization case study (E4e): helps FW, hurts MIS.
#[test]
fn vector_case_study_shape() {
    let cfg = DeviceConfig::pac_a10();
    let fw = pipefwd::workloads::by_name("fw").unwrap();
    let ff = run_workload(fw.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg)
        .unwrap();
    let v4 = run_workload(fw.as_ref(), Variant::Vectorized { width: 4, depth: 1 }, Scale::Tiny, &cfg)
        .unwrap();
    let gain = ff.metrics.seconds / v4.metrics.seconds;
    assert!(gain > 1.5, "fw vec4 gain = {gain} (paper ~3x)");

    let mis = pipefwd::workloads::by_name("mis").unwrap();
    let ff = run_workload(mis.as_ref(), Variant::FeedForward { depth: 1 }, Scale::Tiny, &cfg)
        .unwrap();
    let v4 =
        run_workload(mis.as_ref(), Variant::Vectorized { width: 4, depth: 1 }, Scale::Tiny, &cfg)
            .unwrap();
    let gain = ff.metrics.seconds / v4.metrics.seconds;
    assert!(gain < 1.2, "mis vec4 should not gain, got {gain}");
}
