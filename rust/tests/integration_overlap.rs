//! PR-8 launch-graph overlap integration: (1) the `--overlap` axis is
//! additive — overlap-off signatures, keys and measurements are byte
//! for byte the pre-refactor path; (2) the E9 study is deterministic
//! under a parallel engine; (3) overlap strictly wins on the graph
//! benchmarks whose splits actually admit wavefronts, and (4) NW's
//! depth-sensitive chain is provably *never* overlapped — the graph
//! scheduler collapses to the sequential DES bit for bit.

use pipefwd::coordinator::engine::{
    content_key, content_key_with, content_signature, content_signature_with, GRAPH_TRIO,
};
use pipefwd::coordinator::{Engine, ExperimentId};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::{by_name, Scale};

const FF1: Variant = Variant::FeedForward { depth: 1 };

/// The store-compatibility half of the acceptance criteria: with
/// `overlap = false` the 6-argument signature/key forms are byte for
/// byte the 5-argument ones (every pre-PR-8 store record stays a warm
/// hit), and no overlap marker leaks into off signatures. Overlap-on
/// keys are distinct addresses.
#[test]
fn overlap_off_signatures_and_keys_are_the_pre_refactor_bytes() {
    for name in GRAPH_TRIO.iter().chain(["nw"].iter()) {
        let w = by_name(name).unwrap();
        let app = w.build(FF1).unwrap();
        for use_des in [false, true] {
            let cfg = DeviceConfig::pac_a10();
            let plain = content_signature(name, &app, Scale::Tiny, &cfg, use_des);
            let off = content_signature_with(name, &app, Scale::Tiny, &cfg, use_des, false);
            assert_eq!(plain, off, "{name}: overlap-off signature drifted");
            assert!(!off.contains("overlap"), "{name}: overlap marker in an off signature");
            assert_eq!(
                content_key(name, &app, Scale::Tiny, &cfg, use_des),
                content_key_with(name, &app, Scale::Tiny, &cfg, use_des, false),
                "{name}: overlap-off key drifted"
            );
            let on = content_signature_with(name, &app, Scale::Tiny, &cfg, use_des, true);
            assert!(on.ends_with("overlap=on\n"), "{name}: on signature missing marker");
            assert_ne!(
                content_key_with(name, &app, Scale::Tiny, &cfg, use_des, false),
                content_key_with(name, &app, Scale::Tiny, &cfg, use_des, true),
                "{name}: overlap must be a distinct store address"
            );
        }
    }
}

/// An overlap-on engine answering with `overlap = false` through
/// `measure_opts` returns exactly what a default (pre-refactor) engine
/// returns — the off leg rides the identical code path.
#[test]
fn overlap_off_measurements_match_the_default_engine() {
    let default_engine = Engine::new(DeviceConfig::pac_a10(), 2).with_des(true);
    let overlap_engine = Engine::new(DeviceConfig::pac_a10(), 2).with_des(true).with_overlap(true);
    for name in GRAPH_TRIO {
        let w = by_name(name).unwrap();
        let base = default_engine.measure(w.as_ref(), FF1, Scale::Tiny).unwrap();
        let off = overlap_engine.measure_opts(w.as_ref(), FF1, Scale::Tiny, true, false).unwrap();
        assert_eq!(base, off, "{name}: overlap-off leg diverged from the default engine");
        assert!(!off.variant.ends_with("+ov"), "{name}: off leg must not carry the +ov suffix");
    }
}

/// The paper's claim, as an invariant: on the graph benchmarks whose
/// kernel splits admit concurrent wavefronts, the overlapped schedule
/// models strictly less time than the sequential chain, reports fewer
/// wavefronts than launches, and tags the variant `+ov`.
#[test]
fn overlap_strictly_wins_on_bfs_and_pagerank() {
    let engine = Engine::new(DeviceConfig::pac_a10(), 2);
    for name in ["bfs", "pagerank"] {
        let w = by_name(name).unwrap();
        let seq = engine.measure_opts(w.as_ref(), FF1, Scale::Tiny, true, false).unwrap();
        let ov = engine.measure_opts(w.as_ref(), FF1, Scale::Tiny, true, true).unwrap();
        assert!(
            ov.seconds < seq.seconds,
            "{name}: overlapped {} not strictly below sequential {}",
            ov.seconds,
            seq.seconds
        );
        assert!(
            ov.launches < seq.launches,
            "{name}: {} wavefronts vs {} launches — no overlap happened",
            ov.launches,
            seq.launches
        );
        assert!(ov.variant.ends_with("+ov"), "{name}: overlapped variant is {}", ov.variant);
    }
}

/// NW's RMW chain must never be overlapped: the dependence pass keeps
/// the chain, so the overlapped measurement has as many wavefronts as
/// launches and the graph DES reproduces the sequential cycle count
/// bit for bit.
#[test]
fn nw_chain_is_never_overlapped() {
    let engine = Engine::new(DeviceConfig::pac_a10(), 2);
    let nw = by_name("nw").unwrap();
    let seq = engine.measure_opts(nw.as_ref(), FF1, Scale::Tiny, true, false).unwrap();
    let ov = engine.measure_opts(nw.as_ref(), FF1, Scale::Tiny, true, true).unwrap();
    assert_eq!(
        ov.launches, seq.launches,
        "nw: wavefront count must equal launch count (chain preserved)"
    );
    assert_eq!(ov.cycles, seq.cycles, "nw: graph DES over a chain must be bit-identical");
}

/// E9 under a serial and an 8-way engine renders byte-identically —
/// the graph scheduler introduces no nondeterminism into the results
/// sink.
#[test]
fn e9_is_deterministic_under_parallel_engines() {
    let render = |jobs: usize| {
        let e = Engine::new(DeviceConfig::pac_a10(), jobs).with_overlap(true);
        let tables = e.run_experiment(ExperimentId::E9, Scale::Tiny);
        let mut out = String::new();
        for t in &tables {
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        out.push_str(&e.bench_json(Scale::Tiny, &[ExperimentId::E9]));
        out
    };
    assert_eq!(render(1), render(8), "E9 must not depend on engine parallelism");
}
