//! PR-3 tune integration: the depth×replication autotuner must (1) find
//! a config within 5% of the exhaustive sweep's best for the E4 sweep
//! trio while spending strictly fewer probes than the exhaustive grid,
//! (2) replay byte-identically from a warm store with **zero**
//! simulations, and (3) drive `Engine::best_ff` when a tuner is attached.

use pipefwd::coordinator::tune::{run_tune, Policy, Space, TuneRequest};
use pipefwd::coordinator::{Engine, Store, TuneSpec};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::workloads::Scale;
use std::path::PathBuf;

const TRIO: [&str; 3] = ["fw", "hotspot", "mis"];

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pipefwd-tune-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn trio_request(policy: Policy) -> TuneRequest {
    TuneRequest {
        benches: TRIO.iter().map(|s| s.to_string()).collect(),
        policy,
        budget: 40,
        replication: false,
        scale: Scale::Tiny,
        reference: true,
    }
}

/// The acceptance proof: golden-section finds a config within 5% of the
/// exhaustive best using strictly fewer search probes than the
/// exhaustive grid, and a warm-store rerun is byte-identical with
/// `simulations() == 0`.
#[test]
fn golden_tune_cold_vs_warm_is_byte_identical_with_zero_simulations() {
    let dir = tmp_dir("golden-warm");
    let req = trio_request(Policy::Golden);

    let cold = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let cold_report = run_tune(&cold, &req).unwrap();
    assert!(cold.simulations() > 0, "cold run must actually simulate");
    // PR-4 trace tier: however many depths the search and the exhaustive
    // reference probe, the functional interpreter runs once per workload
    assert_eq!(
        cold.trace_runs(),
        TRIO.len() as u64,
        "cold tune must run the interpreter exactly once per (workload, scale)"
    );
    assert!(cold.trace_hits() > 0, "the other probes replay the shared trace");
    let cold_table = cold_report.table().to_markdown();
    let cold_json = cold_report.to_json().to_pretty();

    for o in &cold_report.outcomes {
        let (_, chosen_s) = o.chosen.expect("search must find a config for the trio");
        let (_, exh_s) = o.exhaustive.expect("reference requested");
        assert!(
            chosen_s <= exh_s * 1.05,
            "{}: chosen {chosen_s} not within 5% of exhaustive best {exh_s}",
            o.workload
        );
        assert!(
            o.probes < o.space,
            "{}: search spent {} probes, exhaustive grid is only {}",
            o.workload,
            o.probes,
            o.space
        );
        assert!(o.probes <= req.budget, "{}: budget overrun", o.workload);
    }

    // a fresh engine on the same store replays the search without one
    // simulation and reproduces the report byte for byte
    let warm = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let warm_report = run_tune(&warm, &req).unwrap();
    assert_eq!(warm.simulations(), 0, "warm store must answer every probe");
    assert_eq!(warm.trace_runs(), 0, "warm store must never re-run the interpreter");
    assert_eq!(warm.trace_hits(), 0, "full-key hits answer before the trace tier");
    assert!(warm.store_hits() > 0);
    assert_eq!(warm_report.table().to_markdown(), cold_table);
    assert_eq!(warm_report.to_json().to_pretty(), cold_json);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Successive halving over the depth×replication product space: stays in
/// budget, finds a config no slower than plain ff(d1), and replays warm.
#[test]
fn successive_halving_searches_the_product_space_within_budget() {
    let dir = tmp_dir("sh-warm");
    let req = TuneRequest { replication: true, ..trio_request(Policy::Sh) };

    let cold = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let cold_report = run_tune(&cold, &req).unwrap();
    for o in &cold_report.outcomes {
        let (_, chosen_s) = o.chosen.expect("sh must find a config");
        assert!(o.probes <= req.budget, "{}: budget overrun ({})", o.workload, o.probes);
        assert_eq!(o.space, Space::new(Scale::Tiny, true).len());
        if let Some(ff1) = o.ff1_seconds {
            assert!(
                chosen_s <= ff1 * 1.0001,
                "{}: chosen {chosen_s} slower than the ff(d1) it also probed ({ff1})",
                o.workload
            );
        }
    }

    let warm = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let warm_report = run_tune(&warm, &req).unwrap();
    assert_eq!(warm.simulations(), 0);
    assert_eq!(warm.trace_runs(), 0, "warm sh rerun must not re-interpret");
    assert_eq!(
        warm_report.to_json().to_pretty(),
        cold_report.to_json().to_pretty(),
        "sh report must replay byte-identically"
    );

    let _ = std::fs::remove_dir_all(&dir);
}

/// PR-8 satellite: the golden bracket is seeded per device. The arria10
/// runs above exercise the zero-fill path (full ladder, unchanged); on
/// stratix10-hbm, whose channel fill cost pushes the optimum deep, the
/// seeded bracket spends strictly fewer probes than the ladder has
/// rungs while still landing within 5% of the exhaustive best.
#[test]
fn hbm_golden_bracket_is_seeded_and_spends_fewer_probes() {
    use pipefwd::coordinator::tune::DEPTH_LADDER;
    let engine = Engine::new(DeviceConfig::stratix10_hbm(), 4);
    let report = run_tune(&engine, &trio_request(Policy::Golden)).unwrap();
    assert_eq!(report.device, "stratix10-hbm");
    for o in &report.outcomes {
        let (_, chosen_s) = o.chosen.expect("seeded search must still find a config");
        assert!(
            o.probes < DEPTH_LADDER.len(),
            "{}: seeded bracket spent {} probes, the full ladder is {}",
            o.workload,
            o.probes,
            DEPTH_LADDER.len()
        );
        let (_, exh_s) = o.exhaustive.expect("reference requested");
        assert!(
            chosen_s <= exh_s * 1.05,
            "{}: seeded choice {chosen_s} not within 5% of exhaustive best {exh_s}",
            o.workload
        );
    }
}

/// The TUNE.json document carries the fields CI consumes, and its
/// counters parse back as integers.
#[test]
fn tune_report_json_is_well_formed() {
    let engine = Engine::new(DeviceConfig::pac_a10(), 2);
    let req = TuneRequest { reference: false, ..trio_request(Policy::Golden) };
    let report = run_tune(&engine, &req).unwrap();
    let doc = pipefwd::util::json::parse(&report.to_json().to_pretty()).unwrap();
    assert_eq!(doc.get("schema").unwrap().as_str(), Some("pipefwd-tune-v1"));
    assert_eq!(doc.get("policy").unwrap().as_str(), Some("golden"));
    assert_eq!(doc.get("budget").unwrap().as_usize(), Some(40));
    // "which depth on which device": the report names its device profile
    assert_eq!(doc.get("device").unwrap().as_str(), Some("arria10"));
    let workloads = doc.get("workloads").unwrap().as_array().unwrap();
    assert_eq!(workloads.len(), TRIO.len());
    for w in workloads {
        assert!(w.get("probes").unwrap().as_usize().is_some());
        assert!(w.get("chosen").unwrap().as_str().is_some(), "trio configs must resolve");
        // no reference requested: the regret columns are null
        assert_eq!(w.get("exhaustive").unwrap(), &pipefwd::util::json::Json::Null);
    }
}

/// With a tuner attached, `Engine::best_ff` consumes tuner output and
/// matches the quality of the exhaustive paper sweep.
#[test]
fn tuned_best_ff_matches_exhaustive_quality() {
    let exhaustive = Engine::new(DeviceConfig::pac_a10(), 2);
    let tuned = Engine::new(DeviceConfig::pac_a10(), 2)
        .with_tuner(TuneSpec { policy: Policy::Golden, budget: 40 });
    for name in TRIO {
        let w = pipefwd::workloads::by_name(name).unwrap();
        let e = exhaustive.best_ff(w.as_ref(), Scale::Tiny).unwrap();
        let t = tuned.best_ff(w.as_ref(), Scale::Tiny).unwrap();
        assert!(
            t.seconds <= e.seconds * 1.05,
            "{name}: tuned best {} not within 5% of exhaustive best {}",
            t.seconds,
            e.seconds
        );
    }
    // NW: deep pipes fail validation; the tuned search must still land
    // on a feasible depth instead of erroring out
    let nw = pipefwd::workloads::by_name("nw").unwrap();
    let m = tuned.best_ff(nw.as_ref(), Scale::Tiny).unwrap();
    assert!(m.variant.starts_with("ff(d"), "unexpected variant {}", m.variant);
}

/// The depth-sweep table grows a "tuned best" column when a tuner is
/// attached (E4 consuming tuner output).
#[test]
fn depth_sweep_annotates_tuned_choice() {
    let plain = Engine::new(DeviceConfig::pac_a10(), 2);
    let tuned = Engine::new(DeviceConfig::pac_a10(), 2)
        .with_tuner(TuneSpec { policy: Policy::Golden, budget: 40 });
    let base = plain.depth_sweep(&["fw"], Scale::Tiny, &[1, 100]);
    let annotated = tuned.depth_sweep(&["fw"], Scale::Tiny, &[1, 100]);
    assert_eq!(base.header.len() + 1, annotated.header.len());
    assert_eq!(annotated.header.last().unwrap(), "tuned best");
    let last = annotated.rows[0].last().unwrap();
    assert!(last.starts_with("ff(d"), "tuned column must name a config, got {last}");
}
