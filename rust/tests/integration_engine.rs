//! PR-1 engine integration: the parallel experiment engine must produce
//! measurements — and a BENCH_PR1.json results sink — byte-identical to
//! the serial reference path, and its memoization layer must collapse the
//! cross-experiment measurement overlap.

use pipefwd::coordinator::{grid, Cell, Engine, ExperimentId};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::Scale;

/// A reduced grid: three workloads x three variants at Tiny scale, with a
/// deliberately infeasible cell (MIS depth sweep stays feasible; NW
/// replication is rejected) so the error path is covered too.
fn reduced_grid() -> Vec<Cell> {
    let mut cells = vec![];
    for name in ["fw", "hotspot", "mis"] {
        cells.push(Cell::new(name, Variant::Baseline, Scale::Tiny));
        cells.push(Cell::new(name, Variant::FeedForward { depth: 1 }, Scale::Tiny));
        cells.push(Cell::new(name, Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny));
    }
    cells.push(Cell::new("nw", Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny));
    cells
}

#[test]
fn parallel_engine_matches_serial_measurements() {
    let cells = reduced_grid();
    let serial = Engine::new(DeviceConfig::pac_a10(), 1);
    let parallel = Engine::new(DeviceConfig::pac_a10(), 4);
    let a = serial.run_cells(&cells);
    let b = parallel.run_cells(&cells);
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x, y, "cell {i} ({:?}) diverged between serial and parallel", cells[i]);
    }
    // the infeasible NW cell errored identically rather than disappearing
    assert!(a.last().unwrap().is_err());
}

#[test]
fn parallel_engine_bench_json_is_byte_identical() {
    let cells = reduced_grid();
    let serial = Engine::new(DeviceConfig::pac_a10(), 1);
    let parallel = Engine::new(DeviceConfig::pac_a10(), 4);
    let _ = serial.run_cells(&cells);
    let _ = parallel.run_cells(&cells);
    let a = serial.bench_json(Scale::Tiny, &[ExperimentId::E2]);
    let b = parallel.bench_json(Scale::Tiny, &[ExperimentId::E2]);
    assert_eq!(a, b, "results sink must not depend on scheduling");
    assert!(a.contains("pipefwd-bench-v1"));
    assert!(a.contains("\"workload\""));
}

#[test]
fn duplicate_cells_simulate_once() {
    let mut cells = reduced_grid();
    cells.extend(reduced_grid()); // every cell twice
    let engine = Engine::new(DeviceConfig::pac_a10(), 4);
    let results = engine.run_cells(&cells);
    assert_eq!(results.len(), cells.len());
    // 9 feasible configurations; the NW replication cell is rejected at
    // build time and never enters the memo table.
    assert_eq!(engine.cache_len(), 9, "cache must collapse duplicates");
    assert!(
        engine.cache_hits() >= 9,
        "duplicated grid must be served from the cache (hits={})",
        engine.cache_hits()
    );
    // first and second copy of each cell agree exactly
    let half = cells.len() / 2;
    for i in 0..half {
        assert_eq!(results[i], results[i + half]);
    }
}

#[test]
fn e2_grid_runs_end_to_end_at_tiny_scale() {
    let engine = Engine::new(DeviceConfig::pac_a10(), 4);
    let tables = engine.run_experiment(ExperimentId::E2, Scale::Tiny);
    assert_eq!(tables.len(), 1);
    assert!(!tables[0].rows.is_empty(), "figure 4 table must have rows");
    assert!(!engine.measurements().is_empty());
    // every simulated grid cell for E2 exists and is well-formed
    assert!(!grid(ExperimentId::E2, Scale::Tiny).is_empty());
}
