//! PR-1 engine integration: the parallel experiment engine must produce
//! measurements — and a BENCH_PR1.json results sink — byte-identical to
//! the serial reference path, and its memoization layer must collapse the
//! cross-experiment measurement overlap.

use pipefwd::coordinator::{
    grid, merge_bench_json, shard_cells, Cell, Engine, ExperimentId, Store,
};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::Scale;

/// A reduced grid: three workloads x three variants at Tiny scale, with a
/// deliberately infeasible cell (MIS depth sweep stays feasible; NW
/// replication is rejected) so the error path is covered too.
fn reduced_grid() -> Vec<Cell> {
    let mut cells = vec![];
    for name in ["fw", "hotspot", "mis"] {
        cells.push(Cell::new(name, Variant::Baseline, Scale::Tiny));
        cells.push(Cell::new(name, Variant::FeedForward { depth: 1 }, Scale::Tiny));
        cells.push(Cell::new(name, Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny));
    }
    cells.push(Cell::new("nw", Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny));
    cells
}

/// PR-4 acceptance: a cold depth sweep over D depths performs exactly one
/// interpreter run per (workload, scale) — not D — and the parallel
/// engine's sink bytes still match the serial reference.
#[test]
fn cold_depth_sweep_interprets_once_per_workload() {
    let mut cells = vec![];
    for name in ["fw", "hotspot", "mis"] {
        for d in [1usize, 100, 1000] {
            cells.push(Cell::new(name, Variant::FeedForward { depth: d }, Scale::Tiny));
        }
    }
    let parallel = Engine::new(DeviceConfig::pac_a10(), 4);
    let a = parallel.run_cells(&cells);
    assert_eq!(parallel.simulations(), 9);
    assert_eq!(parallel.trace_runs(), 3, "one interpreter run per (workload, scale)");
    assert_eq!(parallel.trace_hits(), 6);

    let serial = Engine::new(DeviceConfig::pac_a10(), 1);
    let b = serial.run_cells(&cells);
    assert_eq!(serial.trace_runs(), 3);
    assert_eq!(a, b, "trace sharing must not depend on scheduling");
    assert_eq!(
        parallel.bench_json(Scale::Tiny, &[]),
        serial.bench_json(Scale::Tiny, &[]),
        "sink bytes must be identical under trace replay"
    );
}

/// PR-5 acceptance: the irregular graph trio's depth ladders collapse to
/// one interpreter run per (workload, scale) under the new benign-race
/// vouches — bfs (frontier flag is a monotonic OR over disjoint
/// visited/unvisited index sets), color (color array written strictly
/// behind the conflict reads, one round later), pagerank (rank sum
/// buffer read only next iteration) — and every replayed rung is
/// bit-identical to an independent cold run at that depth.
#[test]
fn vouched_graph_trio_depth_ladders_share_one_trace() {
    let mut cells = vec![];
    for name in ["bfs", "color", "pagerank"] {
        for d in [1usize, 100, 1000] {
            cells.push(Cell::new(name, Variant::FeedForward { depth: d }, Scale::Tiny));
        }
    }
    let sweep = Engine::new(DeviceConfig::pac_a10(), 1);
    let results = sweep.run_cells(&cells);
    assert_eq!(sweep.simulations(), 9, "each depth is still a distinct measurement");
    assert_eq!(sweep.trace_runs(), 3, "at most one interpreter run per (workload, scale)");
    assert_eq!(sweep.trace_hits(), 6, "the other two rungs replay the shared trace");

    // replay fidelity: every rung equals what a cold engine computes for
    // that depth alone — the sink's byte-identity rests on this
    for (cell, replayed) in cells.iter().zip(&results) {
        let cold = Engine::new(DeviceConfig::pac_a10(), 1);
        let fresh = cold.measure(
            pipefwd::workloads::by_name(&cell.workload).unwrap().as_ref(),
            cell.variant,
            cell.scale,
        );
        assert_eq!(
            replayed.clone(),
            fresh,
            "{} depth ladder replay diverged from a cold run at {:?}",
            cell.workload,
            cell.variant
        );
    }

    // and through a persistent store, the *warm* trio ladder does zero
    // interpreter work at all (the acceptance criterion verbatim)
    let dir = std::env::temp_dir()
        .join(format!("pipefwd-int-{}-vouch-trio", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    {
        let seed = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
        let _ = seed.run_cells(&cells);
        assert_eq!(seed.trace_runs(), 3);
    }
    let warm = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let warm_results = warm.run_cells(&cells);
    assert_eq!(warm.trace_runs(), 0, "warm graph-trio ladder must not interpret");
    assert_eq!(warm.simulations(), 0, "warm graph-trio ladder must not simulate");
    assert_eq!(warm_results, results, "warm results must match the cold ladder exactly");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn parallel_engine_matches_serial_measurements() {
    let cells = reduced_grid();
    let serial = Engine::new(DeviceConfig::pac_a10(), 1);
    let parallel = Engine::new(DeviceConfig::pac_a10(), 4);
    let a = serial.run_cells(&cells);
    let b = parallel.run_cells(&cells);
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x, y, "cell {i} ({:?}) diverged between serial and parallel", cells[i]);
    }
    // the infeasible NW cell errored identically rather than disappearing
    assert!(a.last().unwrap().is_err());
}

#[test]
fn parallel_engine_bench_json_is_byte_identical() {
    let cells = reduced_grid();
    let serial = Engine::new(DeviceConfig::pac_a10(), 1);
    let parallel = Engine::new(DeviceConfig::pac_a10(), 4);
    let _ = serial.run_cells(&cells);
    let _ = parallel.run_cells(&cells);
    let a = serial.bench_json(Scale::Tiny, &[ExperimentId::E2]);
    let b = parallel.bench_json(Scale::Tiny, &[ExperimentId::E2]);
    assert_eq!(a, b, "results sink must not depend on scheduling");
    assert!(a.contains("pipefwd-bench-v1"));
    assert!(a.contains("\"workload\""));
}

/// The PR-2 acceptance proof: one process, eight workers, and a 3-shard
/// run reassembled by `merge` all emit byte-identical BENCH_PR1.json —
/// and a second warm-store pass performs zero new simulations.
#[test]
fn sharded_run_plus_merge_is_byte_identical_to_serial() {
    let cfg = DeviceConfig::pac_a10();
    let scale = Scale::Tiny;
    let exps = [ExperimentId::E2];

    // `run` (1 process, serial)
    let serial = Engine::new(cfg.clone(), 1);
    serial.prewarm(ExperimentId::E2, scale);
    let a = serial.bench_json(scale, &exps);

    // `run --jobs 8`
    let parallel = Engine::new(cfg.clone(), 8);
    parallel.prewarm(ExperimentId::E2, scale);
    let b = parallel.bench_json(scale, &exps);

    // `run --shard i/3` in three independent store directories + `merge`
    let dirs: Vec<_> = (1..=3)
        .map(|i| {
            let d = std::env::temp_dir()
                .join(format!("pipefwd-int-{}-shard-{i}", std::process::id()));
            let _ = std::fs::remove_dir_all(&d);
            d
        })
        .collect();
    let full = grid(ExperimentId::E2, scale);
    let mut sharded_cells = 0;
    for (i, dir) in dirs.iter().enumerate() {
        let shard = Engine::new(cfg.clone(), 2).with_store(Store::open(dir).unwrap());
        let slice = shard_cells(&full, i + 1, 3).expect("valid shard index");
        sharded_cells += slice.len();
        let _ = shard.run_cells(&slice);
    }
    assert_eq!(sharded_cells, full.len(), "3 shards must cover the whole E2 grid");
    let stores: Vec<Store> = dirs.iter().map(|d| Store::open(d).unwrap()).collect();
    let c = merge_bench_json(&stores, &exps, scale, &cfg, false).unwrap();

    assert_eq!(a, b, "serial vs --jobs 8 sink diverged");
    assert_eq!(a, c, "serial vs sharded+merged sink diverged");

    // warm-store rerun: the full grid is answered without one simulation
    let warm = Engine::new(cfg.clone(), 4).with_store(Store::open(&dirs[0]).unwrap());
    for s in &stores[1..] {
        warm.store().unwrap().merge_from(s).unwrap();
    }
    let _ = warm.run_cells(&full);
    assert_eq!(warm.simulations(), 0, "warm store must answer the entire grid");
    assert_eq!(warm.bench_json(scale, &exps), a, "warm rerun sink diverged");

    for d in &dirs {
        let _ = std::fs::remove_dir_all(d);
    }
}

#[test]
fn duplicate_cells_simulate_once() {
    let mut cells = reduced_grid();
    cells.extend(reduced_grid()); // every cell twice
    let engine = Engine::new(DeviceConfig::pac_a10(), 4);
    let results = engine.run_cells(&cells);
    assert_eq!(results.len(), cells.len());
    // 9 feasible configurations; the NW replication cell is rejected at
    // build time and never enters the memo table.
    assert_eq!(engine.cache_len(), 9, "cache must collapse duplicates");
    assert!(
        engine.cache_hits() >= 9,
        "duplicated grid must be served from the cache (hits={})",
        engine.cache_hits()
    );
    // first and second copy of each cell agree exactly
    let half = cells.len() / 2;
    for i in 0..half {
        assert_eq!(results[i], results[i + half]);
    }
}

#[test]
fn e2_grid_runs_end_to_end_at_tiny_scale() {
    let engine = Engine::new(DeviceConfig::pac_a10(), 4);
    let tables = engine.run_experiment(ExperimentId::E2, Scale::Tiny);
    assert_eq!(tables.len(), 1);
    assert!(!tables[0].rows.is_empty(), "figure 4 table must have rows");
    assert!(!engine.measurements().is_empty());
    // every simulated grid cell for E2 exists and is well-formed
    assert!(!grid(ExperimentId::E2, Scale::Tiny).is_empty());
}
