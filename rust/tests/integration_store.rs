//! PR-2 store integration: the persistent content-addressed measurement
//! store must make warm reruns free (zero new simulations), tolerate
//! corrupted entries as misses, and survive concurrent writers — and a
//! sharded run merged back together must reproduce the serial results
//! sink byte for byte (see also `integration_engine.rs`).

use pipefwd::coordinator::store::{key_hex, STORE_SCHEMA};
use pipefwd::coordinator::{
    grid, merge_bench_json, shard_cells, Cell, Engine, ExperimentId, Store,
};
use pipefwd::sim::device::DeviceConfig;
use pipefwd::transform::Variant;
use pipefwd::workloads::{Scale, Workload as _};
use std::path::PathBuf;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pipefwd-int-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Same reduced grid as the engine integration test: three workloads x
/// three variants at Tiny scale plus an infeasible NW replication cell.
fn reduced_grid() -> Vec<Cell> {
    let mut cells = vec![];
    for name in ["fw", "hotspot", "mis"] {
        cells.push(Cell::new(name, Variant::Baseline, Scale::Tiny));
        cells.push(Cell::new(name, Variant::FeedForward { depth: 1 }, Scale::Tiny));
        cells.push(Cell::new(name, Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny));
    }
    cells.push(Cell::new("nw", Variant::MxCx { parts: 2, depth: 1 }, Scale::Tiny));
    cells
}

#[test]
fn warm_store_rerun_does_zero_simulations() {
    let dir = tmp_dir("warm");
    let cells = reduced_grid();

    let cold = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let first = cold.run_cells(&cells);
    assert_eq!(cold.simulations(), 9, "9 feasible unique configs simulate on a cold store");
    assert_eq!(cold.store_hits(), 0);
    assert_eq!(cold.store().unwrap().len(), 9, "every result persisted");

    // a fresh process (new engine, same directory) re-running the same
    // grid must be answered entirely by the store
    let warm = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let second = warm.run_cells(&cells);
    assert_eq!(warm.simulations(), 0, "warm rerun must not simulate anything");
    assert_eq!(warm.store_hits(), 9);
    assert_eq!(first, second, "store round-trip must preserve results exactly");

    let _ = std::fs::remove_dir_all(&dir);
}

/// PR-4 acceptance: the E4 depth trio (fw/hotspot/mis x depths 1/100/1000)
/// is served by the two-tier store. Cold: one interpreter run per
/// workload, nine modelled measurements. Plain warm: nothing runs at all.
/// Warm *trace* tier alone (measurement entries deleted): the model re-runs
/// but the interpreter does not — and the results sink is byte-identical
/// in all three regimes.
#[test]
fn warm_trace_rerun_of_the_depth_trio_is_byte_identical() {
    let dir = tmp_dir("trace-trio");
    let mut cells = vec![];
    for name in ["fw", "hotspot", "mis"] {
        for d in [1usize, 100, 1000] {
            cells.push(Cell::new(name, Variant::FeedForward { depth: d }, Scale::Tiny));
        }
    }

    let cold = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let _ = cold.run_cells(&cells);
    assert_eq!(cold.simulations(), 9, "every depth is a distinct measurement");
    assert_eq!(cold.trace_runs(), 3, "exactly 1 interpreter run per (workload, scale)");
    assert_eq!(cold.trace_hits(), 6, "the other two rungs replay the shared trace");
    let cold_sink = cold.bench_json(Scale::Tiny, &[]);
    assert_eq!(cold.store().unwrap().trace_keys().len(), 3, "one trace file per workload");

    // plain warm rerun: the measurement tier answers everything
    let warm = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let _ = warm.run_cells(&cells);
    assert_eq!(warm.simulations(), 0);
    assert_eq!(warm.trace_runs(), 0, "a warm rerun must not touch the interpreter");
    assert_eq!(warm.trace_hits(), 0, "full-key hits answer before the trace tier");
    assert_eq!(warm.bench_json(Scale::Tiny, &[]), cold_sink);

    // delete the measurement tier, keep the traces: the model re-runs
    // from persisted traces and reproduces the sink byte for byte
    std::fs::remove_dir_all(dir.join("entries")).unwrap();
    let traced = Engine::new(DeviceConfig::pac_a10(), 4).with_store(Store::open(&dir).unwrap());
    let _ = traced.run_cells(&cells);
    assert_eq!(traced.trace_runs(), 0, "persisted traces must answer the interpreter tier");
    assert_eq!(traced.trace_hits(), 9);
    assert_eq!(traced.simulations(), 9, "the cheap modelling tier re-runs");
    assert_eq!(traced.bench_json(Scale::Tiny, &[]), cold_sink, "trace replay must be byte-exact");

    let _ = std::fs::remove_dir_all(&dir);
}

/// The v3 -> v4 schema bump must orphan stale *trace* entries exactly like
/// measurement entries: a v3-stamped trace (inline profiles, no pool refs)
/// reads as a miss and the interpreter re-runs.
#[test]
fn stale_schema_trace_entries_read_as_misses() {
    let dir = tmp_dir("trace-stale");
    let cells: Vec<Cell> = [1usize, 100, 1000]
        .iter()
        .map(|d| Cell::new("fw", Variant::FeedForward { depth: *d }, Scale::Tiny))
        .collect();
    {
        let e = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
        let _ = e.run_cells(&cells);
        assert_eq!(e.trace_runs(), 1);
    }
    // stamp every trace as if the previous store version had written it,
    // and drop the measurement tier so the trace tier is actually exercised
    for f in std::fs::read_dir(dir.join("traces")).unwrap() {
        let path = f.unwrap().path();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace(STORE_SCHEMA, "pipefwd-store-v3")).unwrap();
    }
    std::fs::remove_dir_all(dir.join("entries")).unwrap();

    let e = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = e.run_cells(&cells);
    assert_eq!(e.trace_hits(), 2, "only the fresh in-process trace may be shared");
    assert_eq!(e.trace_runs(), 1, "the stale v3 trace must be re-acquired, once");
    let _ = std::fs::remove_dir_all(&dir);
}

/// PR-5 pool-corruption contract at engine level: vandalizing the pool
/// files one workload's trace references degrades exactly that trace to a
/// miss (one re-interpretation) — the other workloads' traces resolve,
/// and the regenerated store reproduces the cold sink byte for byte.
#[test]
fn corrupt_pool_files_degrade_one_trace_and_heal() {
    let dir = tmp_dir("pool-heal");
    let mut cells = vec![];
    for name in ["fw", "hotspot", "mis"] {
        for d in [1usize, 100, 1000] {
            cells.push(Cell::new(name, Variant::FeedForward { depth: d }, Scale::Tiny));
        }
    }
    let cold = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = cold.run_cells(&cells);
    let cold_sink = cold.bench_json(Scale::Tiny, &[]);

    // locate fw's trace via its public content address and garble every
    // pool file it references
    let fw = pipefwd::workloads::by_name("fw").unwrap();
    let app = fw.build(Variant::FeedForward { depth: 1 }).unwrap();
    let tkey =
        pipefwd::coordinator::trace_key("fw", fw.benign_cross_kernel_races(), &app, Scale::Tiny);
    let store = Store::open(&dir).unwrap();
    let refs = store.trace_profile_refs(tkey).expect("fw trace persisted");
    assert!(!refs.is_empty());
    for fnv in &refs {
        let path = dir.join("profiles").join(format!("{}.json", key_hex(*fnv)));
        std::fs::write(&path, "garbage{{{").unwrap();
    }
    // drop the measurement tier so the trace tier actually answers
    std::fs::remove_dir_all(dir.join("entries")).unwrap();

    let warm = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = warm.run_cells(&cells);
    assert_eq!(warm.trace_runs(), 1, "only fw re-interprets");
    assert_eq!(warm.trace_hits(), 8, "hotspot/mis traces + fw's fresh trace replay");
    assert_eq!(warm.bench_json(Scale::Tiny, &[]), cold_sink, "healed sink must be byte-exact");

    // the rewrite healed the pool: a third engine replays everything
    std::fs::remove_dir_all(dir.join("entries")).unwrap();
    let healed = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = healed.run_cells(&cells);
    assert_eq!(healed.trace_runs(), 0, "pool must be fully healed");
    assert_eq!(healed.bench_json(Scale::Tiny, &[]), cold_sink);
    let _ = std::fs::remove_dir_all(&dir);
}

/// PR-5 gc acceptance: a warm store survives `store gc` intact — the
/// warm rerun still replays BENCH_PR1.json byte-identically with zero
/// simulations and zero trace runs — while planted orphans (an
/// unreachable entry, trace, and their pooled profile) are deleted and
/// the manifest is rewritten to exactly the surviving keys.
#[test]
fn gc_keeps_warm_replay_and_deletes_only_orphans() {
    let dir = tmp_dir("gc-warm");
    let mut cells = vec![];
    for name in ["fw", "hotspot", "mis"] {
        for d in [1usize, 100, 1000] {
            cells.push(Cell::new(name, Variant::FeedForward { depth: d }, Scale::Tiny));
        }
    }
    let cold = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = cold.run_cells(&cells);
    let cold_sink = cold.bench_json(Scale::Tiny, &[]);

    // plant orphans under keys no grid replay can produce
    let store = Store::open(&dir).unwrap();
    let entries_before = store.keys().len();
    let traces_before = store.trace_keys().len();
    let profiles_before = store.profile_keys().len();
    store.put(0xDEAD_BEEF, &Err("orphan".into()), false).unwrap();
    let mut orphan_prof = pipefwd::sim::profile::KernelProfile::new("orphan_kernel", 1);
    orphan_prof.sites[0].record(42);
    store
        .put_trace(
            0xFEED_FACE,
            &Ok(pipefwd::workloads::ExecTrace {
                launches: vec![pipefwd::workloads::LaunchRecord {
                    unit: "orphan_kernel".into(),
                    profiles: vec![orphan_prof],
                }],
            }),
        )
        .unwrap();
    assert_eq!(store.profile_keys().len(), profiles_before + 1);

    let reachable = pipefwd::coordinator::reachable_keys(&DeviceConfig::pac_a10());

    // dry run first: same numbers, zero deletion
    let dry = store.gc(&reachable.entries, &reachable.traces, true).unwrap();
    assert_eq!(dry.removed_entries, 1);
    assert_eq!(dry.removed_traces, 1);
    assert_eq!(dry.removed_profiles, 1);
    assert_eq!(store.keys().len(), entries_before + 1, "dry run must not delete");

    let report = store.gc(&reachable.entries, &reachable.traces, false).unwrap();
    assert_eq!(report.kept_entries, entries_before);
    assert_eq!(report.kept_traces, traces_before);
    assert_eq!(report.kept_profiles, profiles_before);
    assert_eq!(report.removed_entries, 1, "only the orphan entry goes");
    assert_eq!(report.removed_traces, 1, "only the orphan trace goes");
    assert_eq!(report.removed_profiles, 1, "only the orphan's pooled profile goes");
    assert!(store.get(0xDEAD_BEEF).is_none());
    assert!(store.get_trace(0xFEED_FACE).is_none());
    assert_eq!(store.load_manifest(), Some(store.keys()), "manifest rewritten post-gc");

    // the gc'd pooled store answers the whole grid with zero work
    let warm = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = warm.run_cells(&cells);
    assert_eq!(warm.simulations(), 0, "post-gc warm rerun must not simulate");
    assert_eq!(warm.trace_runs(), 0, "post-gc warm rerun must not interpret");
    assert_eq!(warm.bench_json(Scale::Tiny, &[]), cold_sink, "post-gc sink must be byte-exact");

    // and with the measurement tier dropped, the gc-surviving traces +
    // pool still reproduce the sink from the interpreter-free path
    std::fs::remove_dir_all(dir.join("entries")).unwrap();
    let traced = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = traced.run_cells(&cells);
    assert_eq!(traced.trace_runs(), 0, "gc must keep every reachable trace + pool file");
    assert_eq!(traced.bench_json(Scale::Tiny, &[]), cold_sink);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupted_store_entries_are_resimulated_not_fatal() {
    let dir = tmp_dir("corrupt");
    let cells = reduced_grid();
    {
        let e = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
        let _ = e.run_cells(&cells);
    }
    // vandalize every entry: truncate one, garble the rest
    let entries = dir.join("entries");
    for (i, f) in std::fs::read_dir(&entries).unwrap().enumerate() {
        let path = f.unwrap().path();
        if i == 0 {
            let text = std::fs::read_to_string(&path).unwrap();
            std::fs::write(&path, &text[..text.len() / 3]).unwrap();
        } else {
            std::fs::write(&path, "garbage{{{").unwrap();
        }
    }
    let e = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let results = e.run_cells(&cells);
    assert_eq!(e.store_hits(), 0, "corrupt entries must read as misses");
    assert_eq!(e.simulations(), 9, "every config re-simulates");
    // the re-simulated results match an uncached reference run exactly
    let reference = Engine::new(DeviceConfig::pac_a10(), 2).run_cells(&cells);
    assert_eq!(results, reference);
    // and the rewritten entries are valid again
    let rewarmed = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = rewarmed.run_cells(&cells);
    assert_eq!(rewarmed.simulations(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn schema_bump_invalidates_all_entries() {
    let dir = tmp_dir("schema");
    let cells = reduced_grid();
    {
        let e = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
        let _ = e.run_cells(&cells);
    }
    // rewrite every entry as if an older store version had produced it
    for f in std::fs::read_dir(dir.join("entries")).unwrap() {
        let path = f.unwrap().path();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, text.replace(STORE_SCHEMA, "pipefwd-store-v0")).unwrap();
    }
    let e = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = e.run_cells(&cells);
    assert_eq!(e.store_hits(), 0, "old-schema entries must not be served");
    assert_eq!(e.simulations(), 9);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_engines_on_one_store_lose_no_records() {
    let dir = tmp_dir("concurrent-engines");
    let cells = reduced_grid();
    std::thread::scope(|s| {
        for _ in 0..3 {
            let dir = &dir;
            let cells = &cells;
            s.spawn(move || {
                let e = Engine::new(DeviceConfig::pac_a10(), 2)
                    .with_store(Store::open(dir).unwrap());
                let _ = e.run_cells(cells);
            });
        }
    });
    let store = Store::open(&dir).unwrap();
    assert_eq!(store.len(), 9, "atomic renames must not lose or duplicate entries");
    for key in store.keys() {
        assert!(store.get(key).is_some(), "entry {} unreadable", key_hex(key));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn merge_reports_missing_shards_instead_of_emitting_a_partial_sink() {
    let d1 = tmp_dir("partial-1");
    let d2 = tmp_dir("partial-2");
    let cfg = DeviceConfig::pac_a10();
    let cells = grid(ExperimentId::E2, Scale::Tiny);
    // run shards 1 and 2 of 3, leave shard 3 missing
    for (i, dir) in [(1usize, &d1), (2, &d2)] {
        let e = Engine::new(cfg.clone(), 2).with_store(Store::open(dir).unwrap());
        let _ = e.run_cells(&shard_cells(&cells, i, 3).expect("valid shard index"));
    }
    let stores = [Store::open(&d1).unwrap(), Store::open(&d2).unwrap()];
    let err = merge_bench_json(&stores, &[ExperimentId::E2], Scale::Tiny, &cfg, false)
        .unwrap_err();
    assert!(err.contains("missing"), "error must name the gap: {err}");
    let _ = std::fs::remove_dir_all(&d1);
    let _ = std::fs::remove_dir_all(&d2);
}

#[test]
fn manifest_covers_every_persisted_entry() {
    let dir = tmp_dir("manifest");
    let e = Engine::new(DeviceConfig::pac_a10(), 2).with_store(Store::open(&dir).unwrap());
    let _ = e.run_cells(&reduced_grid());
    let store = e.store().unwrap();
    store.write_manifest().unwrap();
    assert_eq!(store.load_manifest(), Some(store.keys()));
    let _ = std::fs::remove_dir_all(&dir);
}
